//! `repro serve` — a fault-tolerant batched policy-inference server over
//! a trained checkpoint directory.
//!
//! ```text
//!              accept            bounded conn queue
//!   clients ─▶ acceptor thread ─▶ worker pool (HTTP parse, validate)
//!                                     │ bounded job queue (sync_channel)
//!                                     ▼
//!                               engine thread (deadline-aware
//!                               micro-batcher → one batched PolicyFwd
//!                               per learner per window)
//! ```
//!
//! The robustness contract, end to end:
//! - **overload**: both queues are bounded; a full job queue sheds the
//!   request with `503 + Retry-After` *at admission* (the cheap end),
//!   and jobs whose deadline passes while queued are shed engine-side —
//!   under overload the server does strictly less work per request;
//! - **hostile input**: the strict HTTP layer ([`http`]) and body parser
//!   ([`json`]) turn every malformed byte stream into a structured 4xx;
//!   a handler panic is confined to its connection
//!   (`catch_unwind` → 500) and the server keeps serving;
//! - **slow clients**: socket read/write timeouts (408 / disconnect)
//!   bound what a slow-loris peer can hold;
//! - **hot reload**: `POST /admin/reload` validates the newest
//!   checkpoint *completely off to the side* ([`snapshot`]) and swaps it
//!   in atomically under the snapshot lock; a corrupt candidate is a
//!   structured 409 and the old parameters keep serving, bit-for-bit;
//! - **drain**: SIGINT/SIGTERM stop the acceptor, let accepted
//!   connections and queued jobs finish, then exit 0.
//!
//! Endpoints: `POST /v1/learners/<j>/act`, `GET /healthz`,
//! `GET /readyz`, `GET /v1/meta`, `POST /admin/reload`.

pub mod engine;
pub mod http;
pub mod json;
pub mod snapshot;

use crate::config::ServeConfig;
use crate::serve::engine::{ActJob, EngineConfig, EngineReply};
use crate::serve::snapshot::PolicySnapshot;
use crate::testkit::fault::serve_stall_from_env;
use crate::{log_info, log_warn};
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{SyncSender, TrySendError};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Resolved serving options (config `[serve]` + CLI overrides + fault
/// injection hooks).
pub struct ServeOptions {
    pub port: u16,
    pub batch_window: Duration,
    pub max_batch: usize,
    pub queue_capacity: usize,
    pub workers: usize,
    pub read_timeout: Duration,
    pub write_timeout: Duration,
    pub request_timeout: Duration,
    pub max_body_bytes: usize,
    /// Fault injection: stall the engine this long at startup so tests
    /// can fill the bounded queues deterministically (env
    /// `IALS_SERVE_STALL_MS`, or set directly for in-process tests).
    pub engine_stall: Option<Duration>,
    /// Fault injection: honor the `x-inject-panic` request header by
    /// panicking in the handler (tests the per-connection isolation).
    pub inject_panic: bool,
}

impl ServeOptions {
    /// Resolve from the validated `[serve]` config table, applying the
    /// env fault-injection hook.
    pub fn from_config(cfg: &ServeConfig) -> Result<ServeOptions> {
        Ok(ServeOptions {
            port: cfg.port as u16,
            batch_window: Duration::from_millis(cfg.batch_window_ms),
            max_batch: cfg.max_batch,
            queue_capacity: cfg.queue_capacity,
            workers: cfg.workers,
            read_timeout: Duration::from_millis(cfg.read_timeout_ms),
            write_timeout: Duration::from_millis(cfg.write_timeout_ms),
            request_timeout: Duration::from_millis(cfg.request_timeout_ms),
            max_body_bytes: cfg.max_body_bytes,
            engine_stall: serve_stall_from_env()?.map(Duration::from_millis),
            inject_panic: false,
        })
    }
}

/// State shared by the acceptor, workers and admin handlers.
struct Shared {
    opts: ServeOptions,
    checkpoint_dir: PathBuf,
    snapshot: Arc<RwLock<PolicySnapshot>>,
    jobs: SyncSender<ActJob>,
    /// Accepted-but-unhandled connections, bounded at `queue_capacity`.
    conns: Mutex<VecDeque<TcpStream>>,
    conns_cv: Condvar,
    draining: AtomicBool,
    acceptor_done: AtomicBool,
    /// Serializes hot-reloads (concurrent `POST /admin/reload`s).
    reload_lock: Mutex<()>,
}

/// A running server: spawned threads plus the bound address. Tests drive
/// it in-process; the CLI wraps it in [`run`].
pub struct Server {
    addr: SocketAddr,
    shared: Arc<Shared>,
    acceptor: std::thread::JoinHandle<()>,
    workers: Vec<std::thread::JoinHandle<()>>,
    engine: std::thread::JoinHandle<()>,
}

impl Server {
    /// Load the newest valid checkpoint from `checkpoint_dir`, bind the
    /// loopback port (0 = ephemeral) and start the acceptor, worker pool
    /// and engine thread.
    pub fn spawn(checkpoint_dir: &Path, opts: ServeOptions) -> Result<Server> {
        let snap = snapshot::load_newest_valid(checkpoint_dir)?;
        log_info!(
            "[serve] loaded checkpoint iteration {} ({} learner(s), obs={}, hid={}, act={})",
            snap.iteration,
            snap.stores.len(),
            snap.obs_dim,
            snap.hid,
            snap.act_dim
        );
        let listener = TcpListener::bind(("127.0.0.1", opts.port))
            .with_context(|| format!("binding 127.0.0.1:{}", opts.port))?;
        let addr = listener.local_addr().context("reading the bound address")?;
        let snapshot = Arc::new(RwLock::new(snap));
        let (jobs, jobs_rx) = std::sync::mpsc::sync_channel(opts.queue_capacity);
        let engine_cfg = EngineConfig {
            batch_window: opts.batch_window,
            max_batch: opts.max_batch,
            stall: opts.engine_stall,
        };
        let engine_snapshot = Arc::clone(&snapshot);
        let engine = std::thread::Builder::new()
            .name("serve-engine".to_string())
            .spawn(move || engine::run_engine(jobs_rx, engine_snapshot, engine_cfg))
            .context("spawning the engine thread")?;
        let n_workers = opts.workers;
        let shared = Arc::new(Shared {
            opts,
            checkpoint_dir: checkpoint_dir.to_path_buf(),
            snapshot,
            jobs,
            conns: Mutex::new(VecDeque::new()),
            conns_cv: Condvar::new(),
            draining: AtomicBool::new(false),
            acceptor_done: AtomicBool::new(false),
            reload_lock: Mutex::new(()),
        });
        let acceptor_shared = Arc::clone(&shared);
        let acceptor = std::thread::Builder::new()
            .name("serve-accept".to_string())
            .spawn(move || run_acceptor(listener, acceptor_shared))
            .context("spawning the acceptor thread")?;
        let mut workers = Vec::with_capacity(n_workers);
        for i in 0..n_workers {
            let worker_shared = Arc::clone(&shared);
            let handle = std::thread::Builder::new()
                .name(format!("serve-worker-{i}"))
                .spawn(move || run_worker(worker_shared))
                .with_context(|| format!("spawning worker {i}"))?;
            workers.push(handle);
        }
        Ok(Server { addr, shared, acceptor, workers, engine })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Start draining: stop accepting, let in-flight work finish.
    /// Idempotent; [`Server::join`] completes the drain.
    pub fn begin_shutdown(&self) {
        self.shared.draining.store(true, Ordering::SeqCst);
        self.shared.conns_cv.notify_all();
    }

    /// Complete a graceful drain: join the acceptor, then the workers
    /// (which first empty the accepted-connection queue), then drop the
    /// job-queue handle so the engine finishes queued jobs and exits.
    pub fn join(self) -> Result<()> {
        let Server { shared, acceptor, workers, engine, .. } = self;
        shared.draining.store(true, Ordering::SeqCst);
        acceptor.join().map_err(|_| anyhow::anyhow!("the acceptor thread panicked"))?;
        shared.conns_cv.notify_all();
        for (i, w) in workers.into_iter().enumerate() {
            w.join().map_err(|_| anyhow::anyhow!("worker {i} panicked"))?;
        }
        // Last submitter handle: dropping it disconnects the job queue
        // *after* its queued jobs are delivered, draining the engine.
        drop(shared);
        engine.join().map_err(|_| anyhow::anyhow!("the engine thread panicked"))?;
        Ok(())
    }
}

/// Accept loop: hand connections to the worker pool; shed with a fast
/// 503 when the connection queue itself is full; exit when draining.
fn run_acceptor(listener: TcpListener, shared: Arc<Shared>) {
    if let Err(e) = listener.set_nonblocking(true) {
        log_warn!("[serve] cannot set the listener nonblocking ({e}); drain may lag");
    }
    loop {
        if shared.draining.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nonblocking(false);
                let mut q = shared.conns.lock().unwrap_or_else(|e| e.into_inner());
                if q.len() >= shared.opts.queue_capacity {
                    drop(q);
                    shed_connection(&shared, stream);
                } else {
                    q.push_back(stream);
                    drop(q);
                    shared.conns_cv.notify_one();
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => {
                log_warn!("[serve] accept failed: {e}");
                std::thread::sleep(Duration::from_millis(2));
            }
        }
    }
    shared.acceptor_done.store(true, Ordering::SeqCst);
    shared.conns_cv.notify_all();
}

/// Connection-level load shedding: answer 503 without parsing anything.
fn shed_connection(shared: &Shared, stream: TcpStream) {
    let _ = stream.set_write_timeout(Some(shared.opts.write_timeout));
    let reason = format!(
        "connection queue is full ({} pending) — shedding load",
        shared.opts.queue_capacity
    );
    let body = http::error_body(503, &reason);
    let mut s = &stream;
    let _ = http::write_response(&mut s, 503, &[("retry-after", "1")], &body);
}

/// Worker loop: pop an accepted connection, handle exactly one request
/// on it, repeat. Exits only when draining *and* the acceptor is done
/// *and* the queue is empty — accepted connections always complete.
fn run_worker(shared: Arc<Shared>) {
    loop {
        let stream = {
            let mut q = shared.conns.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if let Some(s) = q.pop_front() {
                    break Some(s);
                }
                let drained = shared.draining.load(Ordering::SeqCst)
                    && shared.acceptor_done.load(Ordering::SeqCst);
                if drained {
                    break None;
                }
                let (guard, _timeout) = shared
                    .conns_cv
                    .wait_timeout(q, Duration::from_millis(100))
                    .unwrap_or_else(|e| e.into_inner());
                q = guard;
            }
        };
        match stream {
            Some(s) => handle_connection(&shared, s),
            None => return,
        }
    }
}

/// Handle one connection with panic isolation: a panic anywhere in
/// parsing or routing is caught, answered with a 500, and confined to
/// this connection — the server keeps serving.
fn handle_connection(shared: &Shared, stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(shared.opts.read_timeout));
    let _ = stream.set_write_timeout(Some(shared.opts.write_timeout));
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        handle_one(shared, &stream);
    }));
    if outcome.is_err() {
        log_warn!("[serve] a request handler panicked; the connection got a 500");
        let body = http::error_body(500, "internal error: the request handler panicked");
        let mut s = &stream;
        let _ = http::write_response(&mut s, 500, &[], &body);
    }
}

/// Read one request, route it, write one response.
fn handle_one(shared: &Shared, mut stream: &TcpStream) {
    let parsed = {
        let mut reader = std::io::BufReader::new(stream);
        http::read_request(&mut reader, shared.opts.max_body_bytes)
    };
    match parsed {
        Err(e) => {
            let body = http::error_body(e.status, &e.reason);
            let _ = http::write_response(&mut stream, e.status, &[], &body);
            if e.drain > 0 {
                discard(stream, e.drain);
            }
        }
        Ok(req) => {
            let resp = route(shared, &req);
            let retry: &[(&str, &str)] =
                if resp.retry_after { &[("retry-after", "1")] } else { &[] };
            let _ = http::write_response(&mut stream, resp.status, retry, &resp.body);
        }
    }
}

/// Read and throw away up to `limit` bytes the client is still sending
/// (bounded by the socket read timeout per chunk), so closing the socket
/// after a refusal does not RST the already-written response away.
fn discard(mut stream: &TcpStream, limit: usize) {
    use std::io::Read as _;
    let mut sink = [0u8; 4096];
    let mut taken = 0usize;
    while taken < limit {
        match stream.read(&mut sink) {
            Ok(0) | Err(_) => break,
            Ok(n) => taken += n,
        }
    }
}

struct Response {
    status: u16,
    retry_after: bool,
    body: Vec<u8>,
}

fn ok_json(body: String) -> Response {
    Response { status: 200, retry_after: false, body: body.into_bytes() }
}

fn reject(status: u16, reason: &str) -> Response {
    Response { status, retry_after: false, body: http::error_body(status, reason) }
}

fn shed(reason: &str) -> Response {
    Response { status: 503, retry_after: true, body: http::error_body(503, reason) }
}

/// Dispatch a parsed request to its handler.
fn route(shared: &Shared, req: &http::Request) -> Response {
    if shared.opts.inject_panic && req.header("x-inject-panic").is_some() {
        panic!("injected panic (x-inject-panic)");
    }
    if let Some(rest) = req.target.strip_prefix("/v1/learners/") {
        if let Some(idx) = rest.strip_suffix("/act") {
            if req.method != "POST" {
                return reject(405, &format!("{} {} — act is POST-only", req.method, req.target));
            }
            return handle_act(shared, idx, &req.body);
        }
    }
    match (req.method.as_str(), req.target.as_str()) {
        ("GET", "/healthz") => ok_json("{\"status\":\"ok\"}".to_string()),
        ("GET", "/readyz") => {
            if shared.draining.load(Ordering::SeqCst) {
                reject(503, "draining")
            } else {
                let snap = shared.snapshot.read().unwrap_or_else(|e| e.into_inner());
                ok_json(format!(
                    "{{\"status\":\"ready\",\"checkpoint_iteration\":{}}}",
                    snap.iteration
                ))
            }
        }
        ("GET", "/v1/meta") => {
            let snap = shared.snapshot.read().unwrap_or_else(|e| e.into_inner());
            ok_json(format!(
                "{{\"checkpoint_iteration\":{},\"learners\":{},\"obs_dim\":{},\"act_dim\":{},\
                 \"hidden\":{},\"policy_model\":\"{}\",\"domain\":\"{}\",\"simulator\":\"{}\"}}",
                snap.iteration,
                snap.stores.len(),
                snap.obs_dim,
                snap.act_dim,
                snap.hid,
                json::escape(&snap.meta.policy_model),
                json::escape(&snap.meta.domain),
                json::escape(&snap.meta.simulator)
            ))
        }
        ("POST", "/admin/reload") => handle_reload(shared),
        (method, target) => reject(404, &format!("no route for {method} {target}")),
    }
}

/// `POST /v1/learners/<j>/act`: validate, submit to the engine with a
/// deadline, block for the reply. Queue-full and expired-deadline paths
/// are the 503 shed contract; an unresponsive engine is a 504.
fn handle_act(shared: &Shared, idx: &str, body: &[u8]) -> Response {
    let Ok(learner) = idx.parse::<usize>() else {
        return reject(404, &format!("learner index {:?} is not an integer", idx));
    };
    let (learners, obs_dim) = {
        let snap = shared.snapshot.read().unwrap_or_else(|e| e.into_inner());
        (snap.stores.len(), snap.obs_dim)
    };
    if learner >= learners {
        return reject(404, &format!("learner {learner} out of range ({learners} learner(s))"));
    }
    let obs = match json::parse_obs(body) {
        Ok(obs) => obs,
        Err(reason) => return reject(400, &reason),
    };
    if obs.len() != obs_dim {
        let reason = format!("obs has {} element(s), the policy wants {obs_dim}", obs.len());
        return reject(400, &reason);
    }
    let (resp_tx, resp_rx) = std::sync::mpsc::sync_channel::<EngineReply>(1);
    let job = ActJob {
        learner,
        obs,
        deadline: Instant::now() + shared.opts.request_timeout,
        resp: resp_tx,
    };
    match shared.jobs.try_send(job) {
        Ok(()) => {}
        Err(TrySendError::Full(_)) => {
            let reason = format!(
                "request queue is full (capacity {}) — shedding load",
                shared.opts.queue_capacity
            );
            return shed(&reason);
        }
        Err(TrySendError::Disconnected(_)) => {
            return shed("the inference engine is shutting down");
        }
    }
    // Small grace past the deadline so the engine's own shed reply (a
    // structured 503) wins over the blunt worker-side 504.
    let wait = shared.opts.request_timeout + Duration::from_millis(250);
    match resp_rx.recv_timeout(wait) {
        Ok(EngineReply::Act { action, value, logits }) => ok_json(format!(
            "{{\"learner\":{learner},\"action\":{action},\"value\":{},\"logits\":{}}}",
            json::num(value),
            json::nums(&logits)
        )),
        Ok(EngineReply::Shed { reason }) => shed(&reason),
        Err(_) => reject(504, "timed out waiting for the inference engine"),
    }
}

/// `POST /admin/reload`: atomic checkpoint hot-reload. The newest file
/// is validated completely off to the side; only a fully valid,
/// geometry-compatible snapshot is swapped in (under the write lock, so
/// every act request sees either all-old or all-new parameters). Any
/// rejection is a structured 409 and the old snapshot keeps serving.
fn handle_reload(shared: &Shared) -> Response {
    let _serialized = shared.reload_lock.lock().unwrap_or_else(|e| e.into_inner());
    let candidate = match snapshot::load_newest_strict(&shared.checkpoint_dir) {
        Ok(snap) => snap,
        Err(e) => {
            log_warn!("[serve] reload rejected: {e:#}");
            return reject(409, &format!("reload rejected; still serving the old snapshot: {e:#}"));
        }
    };
    {
        let cur = shared.snapshot.read().unwrap_or_else(|e| e.into_inner());
        let same_geometry = candidate.stores.len() == cur.stores.len()
            && candidate.obs_dim == cur.obs_dim
            && candidate.hid == cur.hid
            && candidate.act_dim == cur.act_dim
            && candidate.meta.policy_model == cur.meta.policy_model;
        if !same_geometry {
            let reason = format!(
                "reload rejected; the candidate's geometry ({} learner(s), obs={}, hid={}, \
                 act={}, model={}) does not match the serving snapshot ({} learner(s), obs={}, \
                 hid={}, act={}, model={})",
                candidate.stores.len(),
                candidate.obs_dim,
                candidate.hid,
                candidate.act_dim,
                candidate.meta.policy_model,
                cur.stores.len(),
                cur.obs_dim,
                cur.hid,
                cur.act_dim,
                cur.meta.policy_model
            );
            log_warn!("[serve] {reason}");
            return reject(409, &reason);
        }
    }
    let mut cur = shared.snapshot.write().unwrap_or_else(|e| e.into_inner());
    let from = cur.iteration;
    let to = candidate.iteration;
    *cur = candidate;
    drop(cur);
    log_info!("[serve] hot-reloaded checkpoint: iteration {from} -> {to}");
    ok_json(format!("{{\"status\":\"reloaded\",\"from_iteration\":{from},\"to_iteration\":{to}}}"))
}

/// Signal-driven shutdown flag (SIGINT/SIGTERM → drain). A bare
/// `AtomicBool` store is the whole handler — async-signal-safe.
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

extern "C" fn on_shutdown_signal(_sig: i32) {
    SHUTDOWN.store(true, Ordering::SeqCst);
}

#[cfg(unix)]
fn install_signal_handlers() {
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_shutdown_signal);
        signal(SIGTERM, on_shutdown_signal);
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}

/// CLI entry (`repro serve`): spawn the server, print the bound address,
/// serve until SIGINT/SIGTERM, then drain gracefully and return Ok — the
/// process exits 0 on a clean drain.
pub fn run(checkpoint_dir: &Path, opts: ServeOptions) -> Result<()> {
    install_signal_handlers();
    let server = Server::spawn(checkpoint_dir, opts)?;
    // The line tests and scripts parse to find the (possibly ephemeral)
    // port; stdout is flushed so `kill -INT` races nothing.
    println!("serving on http://{}", server.addr());
    use std::io::Write as _;
    let _ = std::io::stdout().flush();
    while !SHUTDOWN.load(Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(50));
    }
    log_info!("[serve] shutdown signal received — draining");
    server.begin_shutdown();
    server.join()?;
    log_info!("[serve] drained cleanly");
    Ok(())
}
