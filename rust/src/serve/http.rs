//! A deliberately small HTTP/1.1 layer over blocking streams — just
//! enough protocol for the serving runtime, parsed *strictly*. The server
//! faces untrusted clients, so the contract here mirrors the durable-blob
//! reader in `util::state`: every malformed input becomes a structured
//! [`HttpError`] carrying a 4xx/5xx status, a stable machine-readable
//! `code`, and a reason naming what was wrong — never a panic, never an
//! unbounded allocation.
//!
//! Scope decisions (all intentional):
//! - **keep-alive by default** (HTTP/1.1 semantics): the connection
//!   handler serves a request *stream* per connection — `Connection:
//!   close` (or HTTP/1.0 without `keep-alive`) closes after the response,
//!   and the server closes unilaterally after a parse error (framing is
//!   untrustworthy past one), at the per-connection request cap, and on
//!   drain. [`Request::wants_close`] + the `close` flag of
//!   [`write_response`] carry that negotiation;
//! - `Content-Length` bodies only; `Transfer-Encoding` is a clean 501;
//! - the request head is capped at [`MAX_HEAD_BYTES`] (431) and the body
//!   at the configured `max_body_bytes` (413), both *before* allocation.

use std::io::{Read, Write};

use crate::serve::json;

/// Upper bound on the request line + headers. 8 KiB matches the common
/// default of production HTTP servers and is far above anything the
/// serving API needs.
pub const MAX_HEAD_BYTES: usize = 8192;

/// A parsed request. Header names are kept as received; lookup via
/// [`Request::header`] is case-insensitive per RFC 9110.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub target: String,
    pub version: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// Case-insensitive header lookup; first match wins.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k.eq_ignore_ascii_case(name)).map(|(_, v)| v.as_str())
    }

    /// Whether the client asked for the connection to close after this
    /// response: `Connection: close`, or HTTP/1.0 without an explicit
    /// `Connection: keep-alive` (1.0 defaults to close, 1.1 to persist).
    pub fn wants_close(&self) -> bool {
        match self.header("connection") {
            Some(v) if v.eq_ignore_ascii_case("close") => true,
            Some(v) if v.eq_ignore_ascii_case("keep-alive") => false,
            _ => self.version == "HTTP/1.0",
        }
    }
}

/// A structured protocol-level rejection: the status the client gets, the
/// stable machine-readable code of the JSON error envelope, and the
/// human-facing message (also the server log line).
#[derive(Debug)]
pub struct HttpError {
    pub status: u16,
    /// Stable machine-readable error code (see [`error_body`]).
    pub code: &'static str,
    pub reason: String,
    /// Bytes the client is known to still be sending (a declared body the
    /// server refused to read). The connection handler discards up to
    /// this many bytes after responding, so closing the socket does not
    /// RST the response away while the client is mid-upload.
    pub drain: usize,
}

fn err(status: u16, code: &'static str, reason: impl Into<String>) -> HttpError {
    HttpError { status, code, reason: reason.into(), drain: 0 }
}

/// Read and parse one request from `stream`. The caller is expected to
/// have set a read timeout on the underlying socket; a timeout surfaces
/// as 408, a peer that hangs up mid-request as 400 ("truncated"). A peer
/// that closes (or stalls) before sending *any* byte is the keep-alive
/// idle path — the connection loop detects that case by peeking before
/// calling here, so both zero-byte outcomes below only fire for clients
/// that opened a connection and never spoke.
pub fn read_request(stream: &mut impl Read, max_body_bytes: usize) -> Result<Request, HttpError> {
    let head_bytes = read_head(stream)?;
    let head = std::str::from_utf8(&head_bytes)
        .map_err(|_| err(400, "bad_request", "request head is not UTF-8"))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let (method, target, version) = parse_request_line(request_line)?;

    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue; // the trailing blank line that ended the head
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(err(
                400,
                "bad_request",
                format!("malformed header line (no ':'): {:?}", clip(line)),
            ));
        };
        if name.is_empty() || name.contains(' ') {
            return Err(err(400, "bad_request", format!("malformed header name: {:?}", clip(name))));
        }
        headers.push((name.trim().to_string(), value.trim().to_string()));
    }

    let mut req = Request { method, target, version, headers, body: Vec::new() };
    if req.header("transfer-encoding").is_some() {
        return Err(err(
            501,
            "not_implemented",
            "transfer-encoding is not supported; send a content-length body",
        ));
    }
    let body_len = match req.header("content-length") {
        None => 0,
        Some(v) => match v.parse::<usize>() {
            Ok(n) => n,
            Err(_) => {
                let reason = format!("content-length is not a non-negative integer: {:?}", clip(v));
                return Err(err(400, "bad_request", reason));
            }
        },
    };
    if body_len > max_body_bytes {
        // Reject on the declared size alone — the body is never read, let
        // alone allocated (the handler discards up to `drain` of it after
        // responding; past that cap an RST is the client's problem).
        return Err(HttpError {
            status: 413,
            code: "payload_too_large",
            reason: format!(
                "declared body of {body_len} bytes exceeds the {max_body_bytes}-byte limit"
            ),
            drain: body_len.min(4 << 20),
        });
    }
    if body_len > 0 {
        let mut body = vec![0u8; body_len];
        stream.read_exact(&mut body).map_err(|e| match e.kind() {
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
                err(408, "request_timeout", format!("timed out reading the {body_len}-byte body"))
            }
            _ => err(400, "bad_request", format!("body truncated: expected {body_len} bytes ({e})")),
        })?;
        req.body = body;
    }
    Ok(req)
}

/// Accumulate bytes until the `\r\n\r\n` head terminator, bounding the
/// head size before any parsing.
fn read_head(stream: &mut impl Read) -> Result<Vec<u8>, HttpError> {
    let mut head = Vec::with_capacity(256);
    let mut byte = [0u8; 1];
    loop {
        match stream.read(&mut byte) {
            Ok(0) => {
                return Err(if head.is_empty() {
                    err(400, "bad_request", "connection closed before any request bytes")
                } else {
                    err(
                        400,
                        "bad_request",
                        format!("truncated head: peer closed after {} byte(s)", head.len()),
                    )
                });
            }
            Ok(_) => {
                head.push(byte[0]);
                if head.len() > MAX_HEAD_BYTES {
                    return Err(err(
                        431,
                        "header_too_large",
                        format!("head exceeds the {MAX_HEAD_BYTES}-byte limit"),
                    ));
                }
                if head.ends_with(b"\r\n\r\n") {
                    return Ok(head);
                }
            }
            Err(e) => {
                return Err(match e.kind() {
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
                        err(408, "request_timeout", "timed out reading the request head")
                    }
                    std::io::ErrorKind::Interrupted => continue,
                    _ => err(400, "bad_request", format!("error reading the request head: {e}")),
                });
            }
        }
    }
}

fn parse_request_line(line: &str) -> Result<(String, String, String), HttpError> {
    let parts: Vec<&str> = line.split(' ').collect();
    if parts.len() != 3 || parts.iter().any(|p| p.is_empty()) {
        return Err(err(
            400,
            "bad_request",
            format!("malformed request line (want 'METHOD /target HTTP/1.1'): {:?}", clip(line)),
        ));
    }
    let (method, target, version) = (parts[0], parts[1], parts[2]);
    if !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(err(400, "bad_request", format!("malformed method: {:?}", clip(method))));
    }
    if !target.starts_with('/') {
        return Err(err(
            400,
            "bad_request",
            format!("request target must start with '/': {:?}", clip(target)),
        ));
    }
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err(err(
            505,
            "http_version_unsupported",
            format!("unsupported HTTP version: {:?}", clip(version)),
        ));
    }
    Ok((method.to_string(), target.to_string(), version.to_string()))
}

/// Bound quoted client input in error messages — garbage requests can be
/// kilobytes long.
fn clip(s: &str) -> String {
    const MAX: usize = 64;
    if s.len() <= MAX {
        s.to_string()
    } else {
        let cut = (0..=MAX).rev().find(|&i| s.is_char_boundary(i)).unwrap_or(0);
        format!("{}…", &s[..cut])
    }
}

/// Write one complete response and flush. `close` decides the
/// `connection:` header — the worker loop closes the socket after a
/// `close` response and keeps serving the connection otherwise.
/// `extra_headers` come before the body — the shed path uses this for
/// `Retry-After`, the deprecated aliases for `Deprecation`/`Link`.
pub fn write_response(
    stream: &mut impl Write,
    status: u16,
    extra_headers: &[(&str, &str)],
    body: &[u8],
    close: bool,
) -> std::io::Result<()> {
    let mut head = format!("HTTP/1.1 {status} {}\r\n", status_text(status));
    head.push_str("content-type: application/json\r\n");
    head.push_str(&format!("content-length: {}\r\n", body.len()));
    head.push_str(if close { "connection: close\r\n" } else { "connection: keep-alive\r\n" });
    for (k, v) in extra_headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()
}

/// The canonical JSON error envelope, one shape for every 4xx/5xx the
/// server emits:
///
/// ```json
/// {"error":{"code":"queue_full","message":"...","retry_after_ms":1000}}
/// ```
///
/// `code` is a stable machine-readable string (clients switch on it;
/// `message` is for operators and may change wording), `retry_after_ms`
/// appears only on shed responses that are worth retrying.
pub fn error_body(code: &str, message: &str, retry_after_ms: Option<u64>) -> Vec<u8> {
    let retry = match retry_after_ms {
        Some(ms) => format!(",\"retry_after_ms\":{ms}"),
        None => String::new(),
    };
    format!(
        "{{\"error\":{{\"code\":\"{}\",\"message\":\"{}\"{retry}}}}}",
        json::escape(code),
        json::escape(message)
    )
    .into_bytes()
}

/// Reason phrases for the statuses the server actually emits.
pub fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        505 => "HTTP Version Not Supported",
        _ => "Error",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &[u8]) -> Result<Request, HttpError> {
        read_request(&mut Cursor::new(raw.to_vec()), 1 << 20)
    }

    #[test]
    fn parses_a_canonical_post() {
        let raw =
            b"POST /v1/learners/0/act HTTP/1.1\r\nHost: x\r\nContent-Length: 12\r\n\r\n{\"obs\": [0]}";
        let req = parse(raw).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.target, "/v1/learners/0/act");
        assert_eq!(req.version, "HTTP/1.1");
        assert_eq!(req.header("content-length"), Some("12"));
        assert_eq!(req.body, b"{\"obs\": [0]}");
        assert!(!req.wants_close(), "HTTP/1.1 defaults to keep-alive");
    }

    #[test]
    fn get_without_body_parses() {
        let req = parse(b"GET /healthz HTTP/1.0\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
    }

    #[test]
    fn connection_negotiation_follows_http_semantics() {
        // (raw request, wants_close)
        for (raw, want) in [
            (&b"GET / HTTP/1.1\r\n\r\n"[..], false),
            (b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n", true),
            (b"GET / HTTP/1.1\r\nConnection: Close\r\n\r\n", true),
            (b"GET / HTTP/1.1\r\nConnection: keep-alive\r\n\r\n", false),
            (b"GET / HTTP/1.0\r\n\r\n", true),
            (b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n", false),
        ] {
            let req = parse(raw).unwrap();
            assert_eq!(req.wants_close(), want, "{raw:?}");
        }
    }

    #[test]
    fn declared_oversized_body_is_413_without_reading_it() {
        // The declared length is absurd and the body bytes are absent; a
        // reader that tried to allocate or read first would block or OOM.
        let e = parse(b"POST /x HTTP/1.1\r\nContent-Length: 99999999999\r\n\r\n").unwrap_err();
        assert_eq!(e.status, 413);
        assert_eq!(e.code, "payload_too_large");
        assert!(e.reason.contains("99999999999"), "{}", e.reason);
        assert_eq!(e.drain, 4 << 20, "the discard hint is capped, not the declared size");
    }

    #[test]
    fn transfer_encoding_is_501() {
        let e = parse(b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n").unwrap_err();
        assert_eq!(e.status, 501);
        assert_eq!(e.code, "not_implemented");
    }

    #[test]
    fn truncated_head_and_body_are_400() {
        let e = parse(b"POST /x HTTP/1.1\r\nContent-Le").unwrap_err();
        assert_eq!(e.status, 400);
        assert!(e.reason.contains("truncated"), "{}", e.reason);
        let e = parse(b"POST /x HTTP/1.1\r\nContent-Length: 50\r\n\r\nshort").unwrap_err();
        assert_eq!(e.status, 400);
        assert!(e.reason.contains("truncated"), "{}", e.reason);
    }

    #[test]
    fn oversized_head_is_431() {
        let mut raw = b"GET /".to_vec();
        raw.extend(vec![b'a'; MAX_HEAD_BYTES + 10]);
        let e = parse(&raw).unwrap_err();
        assert_eq!(e.status, 431);
        assert_eq!(e.code, "header_too_large");
    }

    #[test]
    fn malformed_lines_are_structured_4xx() {
        for (raw, status) in [
            (&b"\r\n\r\n"[..], 400),                                  // empty request line
            (b"GETPOST\r\n\r\n", 400),                                // one-part line
            (b"get /x HTTP/1.1\r\n\r\n", 400),                        // lowercase method
            (b"GET x HTTP/1.1\r\n\r\n", 400),                         // target missing '/'
            (b"GET /x HTTP/2\r\n\r\n", 505),                          // wrong version
            (b"GET /x HTTP/1.1\r\nnocolonhere\r\n\r\n", 400),         // header w/o ':'
            (b"GET /x HTTP/1.1\r\nContent-Length: ten\r\n\r\n", 400), // non-numeric length
            (b"GET /x HTTP/1.1\r\nContent-Length: -1\r\n\r\n", 400),  // negative length
        ] {
            let e = parse(raw).expect_err("must be rejected");
            assert_eq!(e.status, status, "{raw:?}: {}", e.reason);
            assert!(!e.reason.is_empty());
            assert!(!e.code.is_empty(), "every rejection carries a stable code");
        }
    }

    #[test]
    fn non_utf8_head_is_400() {
        let e = parse(b"GET /\xff\xfe HTTP/1.1\r\n\r\n").unwrap_err();
        assert_eq!(e.status, 400);
        assert!(e.reason.contains("UTF-8"), "{}", e.reason);
    }

    #[test]
    fn response_writer_emits_complete_http() {
        let mut out = Vec::new();
        write_response(&mut out, 503, &[("retry-after", "1")], b"{}", true).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"), "{text}");
        assert!(text.contains("content-length: 2\r\n"), "{text}");
        assert!(text.contains("connection: close\r\n"), "{text}");
        assert!(text.contains("retry-after: 1\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n{}"), "{text}");

        let mut out = Vec::new();
        write_response(&mut out, 200, &[], b"{}", false).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("connection: keep-alive\r\n"), "{text}");
    }

    /// The envelope shape the satellite pins: one structured JSON object
    /// for every 4xx/5xx, `code` stable, `retry_after_ms` only when set.
    #[test]
    fn error_envelope_shape_per_status() {
        // Every (status, code) pair the server emits somewhere.
        let emitted: &[(u16, &str)] = &[
            (400, "bad_request"),
            (404, "not_found"),
            (404, "unknown_run"),
            (404, "unknown_learner"),
            (405, "method_not_allowed"),
            (408, "request_timeout"),
            (409, "reload_conflict"),
            (413, "payload_too_large"),
            (431, "header_too_large"),
            (500, "internal"),
            (501, "not_implemented"),
            (503, "queue_full"),
            (503, "deadline_exceeded"),
            (503, "draining"),
            (504, "engine_timeout"),
            (505, "http_version_unsupported"),
        ];
        for &(status, code) in emitted {
            let body = String::from_utf8(error_body(code, "why it failed", None)).unwrap();
            assert_eq!(
                body,
                format!("{{\"error\":{{\"code\":\"{code}\",\"message\":\"why it failed\"}}}}"),
                "status {status}"
            );
            assert_ne!(status_text(status), "Error", "status {status} needs a reason phrase");
        }
        // Shed responses advertise the retry hint inside the envelope too
        // (mirroring the Retry-After header, but machine-readable).
        let body = String::from_utf8(error_body("queue_full", "full", Some(1000))).unwrap();
        assert_eq!(
            body,
            "{\"error\":{\"code\":\"queue_full\",\"message\":\"full\",\"retry_after_ms\":1000}}"
        );
    }

    #[test]
    fn error_body_escapes_json() {
        let body = String::from_utf8(error_body("bad_request", "bad \"quote\"", None)).unwrap();
        assert_eq!(body, "{\"error\":{\"code\":\"bad_request\",\"message\":\"bad \\\"quote\\\"\"}}");
    }
}
