//! A deliberately small HTTP/1.1 layer over blocking streams — just
//! enough protocol for the serving runtime, parsed *strictly*. The server
//! faces untrusted clients, so the contract here mirrors the durable-blob
//! reader in `util::state`: every malformed input becomes a structured
//! [`HttpError`] carrying a 4xx/5xx status and a reason naming what was
//! wrong — never a panic, never an unbounded allocation.
//!
//! Scope decisions (all intentional):
//! - one request per connection (`Connection: close` on every response) —
//!   keep-alive bookkeeping buys nothing for a batch-inference endpoint
//!   and complicates drain;
//! - `Content-Length` bodies only; `Transfer-Encoding` is a clean 501;
//! - the request head is capped at [`MAX_HEAD_BYTES`] (431) and the body
//!   at the configured `max_body_bytes` (413), both *before* allocation.

use std::io::{Read, Write};

use crate::serve::json;

/// Upper bound on the request line + headers. 8 KiB matches the common
/// default of production HTTP servers and is far above anything the
/// serving API needs.
pub const MAX_HEAD_BYTES: usize = 8192;

/// A parsed request. Header names are kept as received; lookup via
/// [`Request::header`] is case-insensitive per RFC 9110.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub target: String,
    pub version: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// Case-insensitive header lookup; first match wins.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k.eq_ignore_ascii_case(name)).map(|(_, v)| v.as_str())
    }
}

/// A structured protocol-level rejection: the status the client gets and
/// the reason that goes into the JSON error body (and the server log).
#[derive(Debug)]
pub struct HttpError {
    pub status: u16,
    pub reason: String,
    /// Bytes the client is known to still be sending (a declared body the
    /// server refused to read). The connection handler discards up to
    /// this many bytes after responding, so closing the socket does not
    /// RST the response away while the client is mid-upload.
    pub drain: usize,
}

fn err(status: u16, reason: impl Into<String>) -> HttpError {
    HttpError { status, reason: reason.into(), drain: 0 }
}

/// Read and parse one request from `stream`. The caller is expected to
/// have set a read timeout on the underlying socket; a timeout surfaces
/// as 408, a peer that hangs up mid-request as 400 ("truncated").
pub fn read_request(stream: &mut impl Read, max_body_bytes: usize) -> Result<Request, HttpError> {
    let head_bytes = read_head(stream)?;
    let head =
        std::str::from_utf8(&head_bytes).map_err(|_| err(400, "request head is not UTF-8"))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let (method, target, version) = parse_request_line(request_line)?;

    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue; // the trailing blank line that ended the head
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(err(400, format!("malformed header line (no ':'): {:?}", clip(line))));
        };
        if name.is_empty() || name.contains(' ') {
            return Err(err(400, format!("malformed header name: {:?}", clip(name))));
        }
        headers.push((name.trim().to_string(), value.trim().to_string()));
    }

    let mut req = Request { method, target, version, headers, body: Vec::new() };
    if req.header("transfer-encoding").is_some() {
        return Err(err(501, "transfer-encoding is not supported; send a content-length body"));
    }
    let body_len = match req.header("content-length") {
        None => 0,
        Some(v) => match v.parse::<usize>() {
            Ok(n) => n,
            Err(_) => {
                let reason = format!("content-length is not a non-negative integer: {:?}", clip(v));
                return Err(err(400, reason));
            }
        },
    };
    if body_len > max_body_bytes {
        // Reject on the declared size alone — the body is never read, let
        // alone allocated (the handler discards up to `drain` of it after
        // responding; past that cap an RST is the client's problem).
        return Err(HttpError {
            status: 413,
            reason: format!(
                "declared body of {body_len} bytes exceeds the {max_body_bytes}-byte limit"
            ),
            drain: body_len.min(4 << 20),
        });
    }
    if body_len > 0 {
        let mut body = vec![0u8; body_len];
        stream.read_exact(&mut body).map_err(|e| match e.kind() {
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
                err(408, format!("timed out reading the {body_len}-byte body"))
            }
            _ => err(400, format!("body truncated: expected {body_len} bytes ({e})")),
        })?;
        req.body = body;
    }
    Ok(req)
}

/// Accumulate bytes until the `\r\n\r\n` head terminator, bounding the
/// head size before any parsing.
fn read_head(stream: &mut impl Read) -> Result<Vec<u8>, HttpError> {
    let mut head = Vec::with_capacity(256);
    let mut byte = [0u8; 1];
    loop {
        match stream.read(&mut byte) {
            Ok(0) => {
                return Err(if head.is_empty() {
                    err(400, "connection closed before any request bytes")
                } else {
                    err(400, format!("truncated head: peer closed after {} byte(s)", head.len()))
                });
            }
            Ok(_) => {
                head.push(byte[0]);
                if head.len() > MAX_HEAD_BYTES {
                    return Err(err(431, format!("head exceeds the {MAX_HEAD_BYTES}-byte limit")));
                }
                if head.ends_with(b"\r\n\r\n") {
                    return Ok(head);
                }
            }
            Err(e) => {
                return Err(match e.kind() {
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
                        err(408, "timed out reading the request head")
                    }
                    std::io::ErrorKind::Interrupted => continue,
                    _ => err(400, format!("error reading the request head: {e}")),
                });
            }
        }
    }
}

fn parse_request_line(line: &str) -> Result<(String, String, String), HttpError> {
    let parts: Vec<&str> = line.split(' ').collect();
    if parts.len() != 3 || parts.iter().any(|p| p.is_empty()) {
        return Err(err(
            400,
            format!("malformed request line (want 'METHOD /target HTTP/1.1'): {:?}", clip(line)),
        ));
    }
    let (method, target, version) = (parts[0], parts[1], parts[2]);
    if !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(err(400, format!("malformed method: {:?}", clip(method))));
    }
    if !target.starts_with('/') {
        return Err(err(400, format!("request target must start with '/': {:?}", clip(target))));
    }
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err(err(505, format!("unsupported HTTP version: {:?}", clip(version))));
    }
    Ok((method.to_string(), target.to_string(), version.to_string()))
}

/// Bound quoted client input in error messages — garbage requests can be
/// kilobytes long.
fn clip(s: &str) -> String {
    const MAX: usize = 64;
    if s.len() <= MAX {
        s.to_string()
    } else {
        let cut = (0..=MAX).rev().find(|&i| s.is_char_boundary(i)).unwrap_or(0);
        format!("{}…", &s[..cut])
    }
}

/// Write one complete response and flush. Every response closes the
/// connection (see module docs). `extra_headers` come before the body —
/// the shed path uses this for `Retry-After`.
pub fn write_response(
    stream: &mut impl Write,
    status: u16,
    extra_headers: &[(&str, &str)],
    body: &[u8],
) -> std::io::Result<()> {
    let mut head = format!("HTTP/1.1 {status} {}\r\n", status_text(status));
    head.push_str("content-type: application/json\r\n");
    head.push_str(&format!("content-length: {}\r\n", body.len()));
    head.push_str("connection: close\r\n");
    for (k, v) in extra_headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()
}

/// The canonical JSON error body: `{"error":{"status":S,"reason":"..."}}`.
pub fn error_body(status: u16, reason: &str) -> Vec<u8> {
    format!("{{\"error\":{{\"status\":{status},\"reason\":\"{}\"}}}}", json::escape(reason))
        .into_bytes()
}

/// Reason phrases for the statuses the server actually emits.
pub fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        505 => "HTTP Version Not Supported",
        _ => "Error",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &[u8]) -> Result<Request, HttpError> {
        read_request(&mut Cursor::new(raw.to_vec()), 1 << 20)
    }

    #[test]
    fn parses_a_canonical_post() {
        let raw =
            b"POST /v1/learners/0/act HTTP/1.1\r\nHost: x\r\nContent-Length: 12\r\n\r\n{\"obs\": [0]}";
        let req = parse(raw).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.target, "/v1/learners/0/act");
        assert_eq!(req.version, "HTTP/1.1");
        assert_eq!(req.header("content-length"), Some("12"));
        assert_eq!(req.body, b"{\"obs\": [0]}");
    }

    #[test]
    fn get_without_body_parses() {
        let req = parse(b"GET /healthz HTTP/1.0\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
    }

    #[test]
    fn declared_oversized_body_is_413_without_reading_it() {
        // The declared length is absurd and the body bytes are absent; a
        // reader that tried to allocate or read first would block or OOM.
        let e = parse(b"POST /x HTTP/1.1\r\nContent-Length: 99999999999\r\n\r\n").unwrap_err();
        assert_eq!(e.status, 413);
        assert!(e.reason.contains("99999999999"), "{}", e.reason);
        assert_eq!(e.drain, 4 << 20, "the discard hint is capped, not the declared size");
    }

    #[test]
    fn transfer_encoding_is_501() {
        let e = parse(b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n").unwrap_err();
        assert_eq!(e.status, 501);
    }

    #[test]
    fn truncated_head_and_body_are_400() {
        let e = parse(b"POST /x HTTP/1.1\r\nContent-Le").unwrap_err();
        assert_eq!(e.status, 400);
        assert!(e.reason.contains("truncated"), "{}", e.reason);
        let e = parse(b"POST /x HTTP/1.1\r\nContent-Length: 50\r\n\r\nshort").unwrap_err();
        assert_eq!(e.status, 400);
        assert!(e.reason.contains("truncated"), "{}", e.reason);
    }

    #[test]
    fn oversized_head_is_431() {
        let mut raw = b"GET /".to_vec();
        raw.extend(vec![b'a'; MAX_HEAD_BYTES + 10]);
        let e = parse(&raw).unwrap_err();
        assert_eq!(e.status, 431);
    }

    #[test]
    fn malformed_lines_are_structured_4xx() {
        for (raw, status) in [
            (&b"\r\n\r\n"[..], 400),                                  // empty request line
            (b"GETPOST\r\n\r\n", 400),                                // one-part line
            (b"get /x HTTP/1.1\r\n\r\n", 400),                        // lowercase method
            (b"GET x HTTP/1.1\r\n\r\n", 400),                         // target missing '/'
            (b"GET /x HTTP/2\r\n\r\n", 505),                          // wrong version
            (b"GET /x HTTP/1.1\r\nnocolonhere\r\n\r\n", 400),         // header w/o ':'
            (b"GET /x HTTP/1.1\r\nContent-Length: ten\r\n\r\n", 400), // non-numeric length
            (b"GET /x HTTP/1.1\r\nContent-Length: -1\r\n\r\n", 400),  // negative length
        ] {
            let e = parse(raw).expect_err("must be rejected");
            assert_eq!(e.status, status, "{raw:?}: {}", e.reason);
            assert!(!e.reason.is_empty());
        }
    }

    #[test]
    fn non_utf8_head_is_400() {
        let e = parse(b"GET /\xff\xfe HTTP/1.1\r\n\r\n").unwrap_err();
        assert_eq!(e.status, 400);
        assert!(e.reason.contains("UTF-8"), "{}", e.reason);
    }

    #[test]
    fn response_writer_emits_complete_http() {
        let mut out = Vec::new();
        write_response(&mut out, 503, &[("retry-after", "1")], b"{}").unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"), "{text}");
        assert!(text.contains("content-length: 2\r\n"), "{text}");
        assert!(text.contains("retry-after: 1\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n{}"), "{text}");
    }

    #[test]
    fn error_body_is_valid_json() {
        let body = String::from_utf8(error_body(400, "bad \"quote\"")).unwrap();
        assert_eq!(body, "{\"error\":{\"status\":400,\"reason\":\"bad \\\"quote\\\"\"}}");
    }
}
