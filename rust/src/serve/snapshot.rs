//! Loading training checkpoints into servable policy snapshots, and the
//! validation protocol behind atomic hot-reload.
//!
//! A [`PolicySnapshot`] is the *read-only* half of a training checkpoint:
//! the per-learner policy parameter stores plus the geometry every act
//! request needs (`obs_dim`, `hid`, `act_dim`). The loop/env state blobs
//! that make checkpoints resumable are parsed past and dropped — serving
//! never steps environments.
//!
//! Two loaders with deliberately different failure semantics:
//! - [`load_newest_valid`] — startup: walk newest-first, skip invalid
//!   files with a warning, serve the first one that fully validates
//!   (mirrors `CheckpointManager::load_latest`). A torn newest checkpoint
//!   must not keep the server down.
//! - [`load_newest_strict`] — hot-reload: the newest file must validate
//!   or the reload is *rejected*. An operator asking "pick up the new
//!   checkpoint" must hear "that file is corrupt", not have the server
//!   silently re-serve something older.
//!
//! Validation is always complete before anything is swapped in: header +
//! CRC (`read_checkpoint_file`), full payload parse, store construction,
//! and a [`PolicyView::resolve`] geometry check per learner.

use crate::log_warn;
use crate::nn::ParamStore;
use crate::runtime::checkpoint::{list_checkpoints, read_checkpoint_file, CKPT_MAGIC, CKPT_VERSION};
use crate::runtime::native::PolicyView;
use crate::runtime::{DType, ModelSpec, TensorSpec};
use crate::util::state::{parse_headered, StateReader};
use anyhow::{Context, Result};
use std::path::Path;

/// Plausibility bound on counts read from a checkpoint payload before any
/// proportional allocation — the serving-side analogue of the
/// `read_headered` length bound. A corrupt count field fails here with
/// both numbers named instead of attempting a huge allocation.
const MAX_PLAUSIBLE: usize = 4096;

/// The tensors of one learner's policy store, in checkpoint order.
type LearnerTensors = Vec<(String, Vec<f32>)>;

/// The config geometry section at the head of every checkpoint payload
/// (written by `MultiLearnerRun::write_checkpoint`).
#[derive(Debug, Clone)]
pub struct CheckpointMeta {
    pub domain: String,
    pub simulator: String,
    pub policy_model: String,
    pub learners: usize,
    pub num_envs: usize,
    pub rollout_len: usize,
    pub total_steps: usize,
    pub eval_every: usize,
    pub rounds_done: usize,
}

/// One learner's section of the payload: its seed and its policy tensors
/// (base params and Adam slots — serving only resolves the base eight).
pub struct LearnerSection {
    pub seed: u64,
    pub tensors: LearnerTensors,
}

/// A fully validated, servable view of one checkpoint: per-learner stores
/// plus the (uniform) policy geometry.
pub struct PolicySnapshot {
    /// Training iteration the checkpoint file encodes (from its name).
    pub iteration: usize,
    pub meta: CheckpointMeta,
    pub stores: Vec<ParamStore>,
    pub seeds: Vec<u64>,
    pub obs_dim: usize,
    pub hid: usize,
    pub act_dim: usize,
}

/// Parse the full checkpoint payload: meta, then every learner section
/// (tensors kept, loop/env state blobs length-checked and dropped), then
/// an exhaustion check — trailing bytes are corruption, not slack.
pub fn parse_checkpoint_payload(payload: &[u8]) -> Result<(CheckpointMeta, Vec<LearnerSection>)> {
    let mut r = StateReader::new(payload);
    let meta = CheckpointMeta {
        domain: r.str().context("reading domain")?.to_string(),
        simulator: r.str().context("reading simulator")?.to_string(),
        policy_model: r.str().context("reading policy model")?.to_string(),
        learners: r.usize().context("reading learner count")?,
        num_envs: r.usize().context("reading num_envs")?,
        rollout_len: r.usize().context("reading rollout_len")?,
        total_steps: r.usize().context("reading total_steps")?,
        eval_every: r.usize().context("reading eval_every")?,
        rounds_done: r.usize().context("reading rounds_done")?,
    };
    anyhow::ensure!(
        meta.learners >= 1 && meta.learners <= MAX_PLAUSIBLE,
        "implausible learner count {} (corrupt payload? bound is {MAX_PLAUSIBLE})",
        meta.learners
    );
    let mut sections = Vec::with_capacity(meta.learners);
    for l in 0..meta.learners {
        let section = (|| -> Result<LearnerSection> {
            let seed = r.u64()?;
            let n_tensors = r.usize()?;
            anyhow::ensure!(
                n_tensors <= MAX_PLAUSIBLE,
                "implausible tensor count {n_tensors} (corrupt payload? bound is {MAX_PLAUSIBLE})"
            );
            let mut tensors = Vec::with_capacity(n_tensors);
            for _ in 0..n_tensors {
                let name = r.str()?.to_string();
                let values = r.f32s()?;
                tensors.push((name, values));
            }
            r.bytes().context("reading the loop-state blob")?;
            r.bytes().context("reading the env-state blob")?;
            Ok(LearnerSection { seed, tensors })
        })()
        .with_context(|| format!("parsing learner {l}'s section"))?;
        sections.push(section);
    }
    r.expect_end().context("checkpoint payload has trailing bytes")?;
    Ok((meta, sections))
}

/// Build a servable snapshot from a validated checkpoint payload: one
/// [`ParamStore`] per learner (synthetic flat-shape spec — serving needs
/// names and lengths, not training shapes), each geometry-checked via
/// [`PolicyView::resolve`], and all learners required to agree.
pub fn snapshot_from_payload(iteration: usize, payload: &[u8]) -> Result<PolicySnapshot> {
    let (meta, sections) = parse_checkpoint_payload(payload)?;
    let mut stores = Vec::with_capacity(sections.len());
    let mut seeds = Vec::with_capacity(sections.len());
    for (l, section) in sections.iter().enumerate() {
        let spec = ModelSpec {
            name: meta.policy_model.clone(),
            params: section
                .tensors
                .iter()
                .map(|(name, values)| TensorSpec {
                    name: name.clone(),
                    dtype: DType::F32,
                    shape: vec![values.len()],
                })
                .collect(),
        };
        let mut store = ParamStore::zeros(&spec);
        for (name, values) in &section.tensors {
            store.set(name, values).with_context(|| format!("loading learner {l}'s tensors"))?;
        }
        PolicyView::resolve(&store)
            .with_context(|| format!("learner {l}'s policy geometry is invalid"))?;
        stores.push(store);
        seeds.push(section.seed);
    }
    let (obs_dim, hid, act_dim) = {
        let v = PolicyView::resolve(&stores[0])?;
        (v.obs_dim, v.hid, v.act_dim)
    };
    for (l, store) in stores.iter().enumerate().skip(1) {
        let v = PolicyView::resolve(store)?;
        anyhow::ensure!(
            (v.obs_dim, v.hid, v.act_dim) == (obs_dim, hid, act_dim),
            "learner {l}'s geometry (obs={}, hid={}, act={}) differs from learner 0's \
             (obs={obs_dim}, hid={hid}, act={act_dim})",
            v.obs_dim,
            v.hid,
            v.act_dim
        );
    }
    Ok(PolicySnapshot { iteration, meta, stores, seeds, obs_dim, hid, act_dim })
}

/// Load and fully validate one checkpoint file into a snapshot.
fn load_file(iter: usize, path: &Path) -> Result<PolicySnapshot> {
    let payload = read_checkpoint_file(path)?;
    snapshot_from_payload(iter, &payload)
        .with_context(|| format!("validating {}", path.display()))
}

/// Startup loader: newest-first with skip-and-warn fallback (see module
/// docs). Errors only when *no* checkpoint in `dir` validates.
pub fn load_newest_valid(dir: &Path) -> Result<PolicySnapshot> {
    let found = list_checkpoints(dir);
    anyhow::ensure!(
        !found.is_empty(),
        "no checkpoint files (ckpt_*.bin) in {} — train first, or point --checkpoint-dir at a \
         run directory",
        dir.display()
    );
    let total = found.len();
    for (iter, path) in found.into_iter().rev() {
        match load_file(iter, &path) {
            Ok(snap) => return Ok(snap),
            Err(e) => log_warn!("[serve] skipping invalid checkpoint: {e:#}"),
        }
    }
    anyhow::bail!("all {total} checkpoint file(s) in {} failed validation", dir.display())
}

/// Hot-reload loader: the newest checkpoint must validate, or the reload
/// is rejected with the reason (see module docs — no silent fallback).
pub fn load_newest_strict(dir: &Path) -> Result<PolicySnapshot> {
    let found = list_checkpoints(dir);
    anyhow::ensure!(!found.is_empty(), "no checkpoint files (ckpt_*.bin) in {}", dir.display());
    let (iter, path) = found.into_iter().next_back().unwrap();
    load_file(iter, &path)
}

/// `repro inspect`: one human-readable line per checkpoint file in `dir`
/// (ascending iteration) — header metadata and geometry for valid files,
/// `CORRUPT` plus the structured reason for invalid ones. Never errors on
/// a bad *file*; only on an empty directory.
pub fn inspect_dir(dir: &Path) -> Result<Vec<String>> {
    let found = list_checkpoints(dir);
    anyhow::ensure!(
        !found.is_empty(),
        "no checkpoint files (ckpt_*.bin) in {}",
        dir.display()
    );
    let mut lines = Vec::with_capacity(found.len());
    for (iter, path) in found {
        lines.push(inspect_file(iter, &path));
    }
    Ok(lines)
}

/// One line of `inspect_dir` output (also exercised directly by tests).
/// Runs the *full* serving validation (header, CRC, payload parse, store
/// construction, geometry) so "OK" here means "this file would serve".
pub fn inspect_file(iter: usize, path: &Path) -> String {
    let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("?").to_string();
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) => return format!("{name}  CORRUPT  unreadable: {e}"),
    };
    // Best-effort header peek for display even when validation fails —
    // the operator wants to see what the file *claims* to be.
    let claimed_version = if bytes.len() >= 12 {
        u32::from_le_bytes(bytes[8..12].try_into().unwrap()).to_string()
    } else {
        "?".to_string()
    };
    let validated = parse_headered(CKPT_MAGIC, CKPT_VERSION, &bytes)
        .and_then(|payload| snapshot_from_payload(iter, payload));
    match validated {
        Ok(snap) => format!(
            "{name}  OK       iter={iter} v{claimed_version} learners={} model={} obs={} hid={} \
             act={} rounds_done={} domain={} sim={} ({} bytes)",
            snap.meta.learners,
            snap.meta.policy_model,
            snap.obs_dim,
            snap.hid,
            snap.act_dim,
            snap.meta.rounds_done,
            snap.meta.domain,
            snap.meta.simulator,
            bytes.len()
        ),
        Err(e) => {
            let n = bytes.len();
            format!("{name}  CORRUPT  iter={iter} v{claimed_version} ({n} bytes): {e:#}")
        }
    }
}
