//! The single-threaded inference engine behind the server: a
//! deadline-aware micro-batcher that coalesces concurrent act requests
//! into one batched `PolicyFwd` per learner on the Sync native engine.
//!
//! Design:
//! - one engine thread owns the only [`EngineScratch`]; worker threads
//!   never touch kernels — they submit [`ActJob`]s over a *bounded*
//!   `sync_channel` (the overload backpressure point: `try_send` failing
//!   with `Full` is what the HTTP layer turns into a 503) and block on a
//!   per-job reply channel;
//! - the batcher waits up to `batch_window` after the first job arrives
//!   (or until `max_batch` jobs are queued), then groups the batch by
//!   learner and runs one [`PolicyView::forward_rows`] per group. Rows
//!   are independent in every kernel, so a batched response is bitwise
//!   identical to a serial one — `tests/serve.rs` asserts exactly that;
//! - jobs whose deadline passed while queued are answered with a shed
//!   reply instead of being computed — under overload the server does
//!   less work, not more;
//! - drain is free: when every submitter handle is dropped, `recv`
//!   returns `Disconnected` *after* delivering all queued jobs, so the
//!   engine finishes in-flight work and exits without a flush protocol.

use crate::runtime::native::{EngineScratch, PolicyView};
use crate::serve::snapshot::PolicySnapshot;
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

/// One act request, routed to learner `learner`.
pub struct ActJob {
    pub learner: usize,
    pub obs: Vec<f32>,
    /// Absolute deadline; jobs still queued past it are shed, not run.
    pub deadline: Instant,
    /// Reply slot (capacity 1; the worker blocks on it with a timeout).
    pub resp: SyncSender<EngineReply>,
}

/// What the engine sends back for one job.
pub enum EngineReply {
    /// Greedy action, value estimate and the full logit row.
    Act { action: usize, value: f32, logits: Vec<f32> },
    /// The job was not computed; `reason` is operator-facing.
    Shed { reason: String },
}

/// Batching knobs (from `[serve]`), plus the test-only startup stall.
pub struct EngineConfig {
    pub batch_window: Duration,
    pub max_batch: usize,
    /// Fault injection: sleep this long before processing the first
    /// batch. Lets the shed/drain tests fill the bounded queue
    /// deterministically. `None` in production.
    pub stall: Option<Duration>,
}

/// Engine thread main loop: collect → batch → reply, until every
/// submitter handle is gone and the queue is drained.
pub fn run_engine(rx: Receiver<ActJob>, snapshot: Arc<RwLock<PolicySnapshot>>, cfg: EngineConfig) {
    if let Some(stall) = cfg.stall {
        std::thread::sleep(stall);
    }
    // Preallocate for the largest band the batcher can form; hot-reload
    // preserves geometry, so this never regrows on the steady-state path.
    let hid = snapshot.read().unwrap_or_else(|e| e.into_inner()).hid;
    let mut scratch = EngineScratch::new(cfg.max_batch * hid, cfg.max_batch * hid);
    loop {
        // Block (with a periodic wake so a dropped channel is noticed)
        // for the first job of the next batch.
        let first = match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(job) => job,
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => return,
        };
        let mut batch = vec![first];
        let window_closes = Instant::now() + cfg.batch_window;
        while batch.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= window_closes {
                break;
            }
            match rx.recv_timeout(window_closes - now) {
                Ok(job) => batch.push(job),
                Err(RecvTimeoutError::Timeout) => break,
                // Keep the jobs we already pulled; they run below and
                // then the outer loop observes the disconnect.
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        let snap = snapshot.read().unwrap_or_else(|e| e.into_inner());
        run_batch(batch, &snap, &mut scratch);
    }
}

/// Run one collected batch: shed expired jobs, group the rest by learner,
/// one row-band forward per group, reply per job. Reply sends ignore
/// errors — a worker that timed out and went away already answered 504.
fn run_batch(batch: Vec<ActJob>, snap: &PolicySnapshot, scratch: &mut EngineScratch) {
    let now = Instant::now();
    // Group job indices by learner, preserving arrival order within each
    // group (grouping must not affect results — rows are independent).
    let mut by_learner: std::collections::BTreeMap<usize, Vec<ActJob>> =
        std::collections::BTreeMap::new();
    for job in batch {
        if now >= job.deadline {
            let reply = EngineReply::Shed {
                reason: "deadline exceeded while queued (server overloaded)".to_string(),
            };
            let _ = job.resp.try_send(reply);
            continue;
        }
        // The HTTP layer validates learner index and obs length against
        // the serving snapshot before submitting; re-check here so a bad
        // job can only ever be shed, never panic the engine thread.
        if job.learner >= snap.stores.len() || job.obs.len() != snap.obs_dim {
            let reason = format!(
                "stale job: learner {} obs_len {} vs snapshot ({} learner(s), obs_dim {})",
                job.learner,
                job.obs.len(),
                snap.stores.len(),
                snap.obs_dim
            );
            let _ = job.resp.try_send(EngineReply::Shed { reason });
            continue;
        }
        by_learner.entry(job.learner).or_default().push(job);
    }
    for (learner, jobs) in by_learner {
        let view = match PolicyView::resolve(&snap.stores[learner]) {
            Ok(v) => v,
            Err(e) => {
                // Unreachable for a validated snapshot; answer rather
                // than wedge the workers if it ever happens.
                for job in jobs {
                    let reason = format!("learner {learner}'s store failed to resolve: {e:#}");
                    let _ = job.resp.try_send(EngineReply::Shed { reason });
                }
                continue;
            }
        };
        let m = jobs.len();
        let mut obs = Vec::with_capacity(m * view.obs_dim);
        for job in &jobs {
            obs.extend_from_slice(&job.obs);
        }
        let mut logits = vec![0.0f32; m * view.act_dim];
        let mut values = vec![0.0f32; m];
        view.forward_rows(m, &obs, &mut logits, &mut values, scratch);
        for (i, job) in jobs.into_iter().enumerate() {
            let row = logits[i * view.act_dim..(i + 1) * view.act_dim].to_vec();
            let action = argmax(&row);
            let reply = EngineReply::Act { action, value: values[i], logits: row };
            let _ = job.resp.try_send(reply);
        }
    }
}

/// Greedy action: index of the largest logit, first on ties — the
/// deterministic serving-side policy (no sampling temperature).
fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in row.iter().enumerate() {
        if x > row[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_is_first_on_ties() {
        assert_eq!(argmax(&[0.0, 1.0, 1.0, -2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[-1.0, -3.0]), 0);
    }
}
