//! The single-threaded inference engine behind the server: a
//! deadline-aware micro-batcher that coalesces concurrent act requests
//! into one batched `PolicyFwd` per learner on the Sync native engine.
//!
//! Design:
//! - one engine thread owns the only [`EngineScratch`]; worker threads
//!   never touch kernels — they submit [`ActJob`]s over a *bounded*
//!   `sync_channel` (the overload backpressure point: `try_send` failing
//!   with `Full` is what the HTTP layer turns into a 503) and block on a
//!   per-job reply channel;
//! - the batcher's coalescing window *adapts to queue depth*: an empty
//!   queue dispatches immediately (a lone request never waits out a
//!   timer), while observed backlog stretches the window toward the
//!   `batch_window` maximum in proportion to how full the batch already
//!   is (see [`adaptive_window`]). Either way the batch is grouped by
//!   learner and run as one [`PolicyView::forward_rows`] per group. Rows
//!   are independent in every kernel, so a batched response is bitwise
//!   identical to a serial one — `tests/serve.rs` asserts exactly that;
//! - jobs whose deadline passed while queued are answered with a shed
//!   reply instead of being computed — under overload the server does
//!   less work, not more;
//! - drain is free: when every submitter handle is dropped, `recv`
//!   returns `Disconnected` *after* delivering all queued jobs, so the
//!   engine finishes in-flight work and exits without a flush protocol.

use crate::runtime::native::{EngineScratch, PolicyView};
use crate::serve::snapshot::PolicySnapshot;
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

/// One act request, routed to learner `learner`.
pub struct ActJob {
    pub learner: usize,
    pub obs: Vec<f32>,
    /// Absolute deadline; jobs still queued past it are shed, not run.
    pub deadline: Instant,
    /// Reply slot (capacity 1; the worker blocks on it with a timeout).
    pub resp: SyncSender<EngineReply>,
}

/// What the engine sends back for one job.
pub enum EngineReply {
    /// Greedy action, value estimate and the full logit row.
    Act { action: usize, value: f32, logits: Vec<f32> },
    /// The job was not computed; `reason` is operator-facing.
    Shed { reason: String },
}

/// Batching knobs (from `[serve]`), plus the test-only startup stall.
pub struct EngineConfig {
    pub batch_window: Duration,
    pub max_batch: usize,
    /// Fault injection: sleep this long before processing the first
    /// batch. Lets the shed/drain tests fill the bounded queue
    /// deterministically. `None` in production.
    pub stall: Option<Duration>,
}

/// Engine thread main loop: collect → batch → reply, until every
/// submitter handle is gone and the queue is drained.
pub fn run_engine(rx: Receiver<ActJob>, snapshot: Arc<RwLock<PolicySnapshot>>, cfg: EngineConfig) {
    if let Some(stall) = cfg.stall {
        std::thread::sleep(stall);
    }
    // Preallocate for the largest band the batcher can form; hot-reload
    // preserves geometry, so this never regrows on the steady-state path.
    let hid = snapshot.read().unwrap_or_else(|e| e.into_inner()).hid;
    let mut scratch = EngineScratch::new(cfg.max_batch * hid, cfg.max_batch * hid);
    loop {
        // Block (with a periodic wake so a dropped channel is noticed)
        // for the first job of the next batch.
        let first = match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(job) => job,
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => return,
        };
        let mut batch = vec![first];
        let opened = Instant::now();
        loop {
            // Greedy drain: everything already queued joins the batch for
            // free — no window is spent collecting work that has arrived.
            while batch.len() < cfg.max_batch {
                match rx.try_recv() {
                    Ok(job) => batch.push(job),
                    Err(_) => break, // empty or disconnected; both end the drain
                }
            }
            if batch.len() >= cfg.max_batch {
                break;
            }
            // The window only exists to *wait* for stragglers, and how
            // long to wait scales with how much backlog was just seen.
            let closes = opened + adaptive_window(batch.len(), cfg.max_batch, cfg.batch_window);
            let now = Instant::now();
            if now >= closes {
                break;
            }
            match rx.recv_timeout(closes - now) {
                Ok(job) => batch.push(job),
                Err(RecvTimeoutError::Timeout) => break,
                // Keep the jobs we already pulled; they run below and
                // then the outer loop observes the disconnect.
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        let snap = snapshot.read().unwrap_or_else(|e| e.into_inner());
        run_batch(batch, &snap, &mut scratch);
    }
}

/// The adaptive coalescing window: how long past the first job's arrival
/// the batcher keeps waiting for more, given it already holds
/// `batch_len` jobs out of `max_batch`.
///
/// - `batch_len == 1` (the queue was empty behind the first job) →
///   **zero**: dispatch immediately, a lone request never pays the
///   window as latency;
/// - backlog → the window stretches linearly with batch fill toward the
///   configured `max` (reached at a full batch, which dispatches anyway).
///
/// Batching stays a pure throughput knob: the window decides only *when*
/// a batch closes, never how its rows are computed, so the
/// bitwise-identical-to-serial guarantee is unaffected.
pub fn adaptive_window(batch_len: usize, max_batch: usize, max: Duration) -> Duration {
    let backlog = batch_len.saturating_sub(1);
    let span = max_batch.saturating_sub(1).max(1);
    max.mul_f64((backlog.min(span)) as f64 / span as f64)
}

/// Run one collected batch: shed expired jobs, group the rest by learner,
/// one row-band forward per group, reply per job. Reply sends ignore
/// errors — a worker that timed out and went away already answered 504.
fn run_batch(batch: Vec<ActJob>, snap: &PolicySnapshot, scratch: &mut EngineScratch) {
    let now = Instant::now();
    // Group job indices by learner, preserving arrival order within each
    // group (grouping must not affect results — rows are independent).
    let mut by_learner: std::collections::BTreeMap<usize, Vec<ActJob>> =
        std::collections::BTreeMap::new();
    for job in batch {
        if now >= job.deadline {
            let reply = EngineReply::Shed {
                reason: "deadline exceeded while queued (server overloaded)".to_string(),
            };
            let _ = job.resp.try_send(reply);
            continue;
        }
        // The HTTP layer validates learner index and obs length against
        // the serving snapshot before submitting; re-check here so a bad
        // job can only ever be shed, never panic the engine thread.
        if job.learner >= snap.stores.len() || job.obs.len() != snap.obs_dim {
            let reason = format!(
                "stale job: learner {} obs_len {} vs snapshot ({} learner(s), obs_dim {})",
                job.learner,
                job.obs.len(),
                snap.stores.len(),
                snap.obs_dim
            );
            let _ = job.resp.try_send(EngineReply::Shed { reason });
            continue;
        }
        by_learner.entry(job.learner).or_default().push(job);
    }
    for (learner, jobs) in by_learner {
        let view = match PolicyView::resolve(&snap.stores[learner]) {
            Ok(v) => v,
            Err(e) => {
                // Unreachable for a validated snapshot; answer rather
                // than wedge the workers if it ever happens.
                for job in jobs {
                    let reason = format!("learner {learner}'s store failed to resolve: {e:#}");
                    let _ = job.resp.try_send(EngineReply::Shed { reason });
                }
                continue;
            }
        };
        let m = jobs.len();
        let mut obs = Vec::with_capacity(m * view.obs_dim);
        for job in &jobs {
            obs.extend_from_slice(&job.obs);
        }
        let mut logits = vec![0.0f32; m * view.act_dim];
        let mut values = vec![0.0f32; m];
        view.forward_rows(m, &obs, &mut logits, &mut values, scratch);
        for (i, job) in jobs.into_iter().enumerate() {
            let row = logits[i * view.act_dim..(i + 1) * view.act_dim].to_vec();
            let action = argmax(&row);
            let reply = EngineReply::Act { action, value: values[i], logits: row };
            let _ = job.resp.try_send(reply);
        }
    }
}

/// Greedy action: index of the largest logit, first on ties — the
/// deterministic serving-side policy (no sampling temperature).
fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in row.iter().enumerate() {
        if x > row[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_is_first_on_ties() {
        assert_eq!(argmax(&[0.0, 1.0, 1.0, -2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[-1.0, -3.0]), 0);
    }

    #[test]
    fn adaptive_window_is_zero_on_an_empty_queue() {
        // One job, nothing behind it: dispatch immediately at any max.
        let max = Duration::from_millis(2);
        assert_eq!(adaptive_window(1, 64, max), Duration::ZERO);
        assert_eq!(adaptive_window(1, 1, max), Duration::ZERO);
    }

    #[test]
    fn adaptive_window_stretches_with_backlog_and_clamps_at_max() {
        let max = Duration::from_millis(100);
        // Linear in fill: half-full batch waits half the max window.
        assert_eq!(adaptive_window(33, 65, max), Duration::from_millis(50));
        // A full (or over-full) batch saturates at the configured max.
        assert_eq!(adaptive_window(64, 64, max), max);
        assert_eq!(adaptive_window(1000, 64, max), max);
        // Monotone non-decreasing in batch depth.
        let mut prev = Duration::ZERO;
        for len in 1..=64 {
            let w = adaptive_window(len, 64, max);
            assert!(w >= prev, "window shrank at len={len}");
            prev = w;
        }
    }

    #[test]
    fn adaptive_window_handles_degenerate_max_batch() {
        // max_batch=1 never waits (the batch is already full at one job);
        // the span guard keeps the division well-defined.
        assert_eq!(adaptive_window(1, 1, Duration::from_millis(5)), Duration::ZERO);
        assert_eq!(adaptive_window(2, 1, Duration::from_millis(5)), Duration::from_millis(5));
    }
}
