//! Figure 6 (§5.4): the M/NM agent × M/NM AIP 2×2 plus the item-lifetime
//! histograms, at a bench-sized budget. Full scale: `repro figure --name fig6`.

use ials::config::ExperimentConfig;
use ials::coordinator::run_figure;
use ials::runtime::Runtime;
use std::rc::Rc;

fn main() {
    ials::util::logger::init();
    let rt = Rc::new(Runtime::load_or_native("artifacts").expect("runtime"));
    let mut base = ExperimentConfig::default();
    base.seeds = vec![1];
    base.ppo.total_steps = 16_384;
    base.eval_every = 8_192;
    base.eval_episodes = 2;
    base.aip.dataset_size = 24_000;
    base.aip.train_epochs = 25;
    base.aip.lr = 3e-3;
    base.results_dir = "results/bench".into();
    run_figure(&rt, "fig6", &base).expect("figure run failed");
}
