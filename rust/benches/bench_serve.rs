//! Serving-runtime throughput: end-to-end `POST .../act` requests/sec
//! against a live in-process [`ials::serve::Server`] over real loopback
//! TCP, sweeping `mode × clients × batch_window_ms`. Two comparisons
//! matter:
//!
//! - **window 0 vs a small coalescing window** at high client counts —
//!   adaptive batching should buy aggregate throughput without hurting
//!   single-client latency (an empty queue dispatches immediately);
//! - **keep-alive vs close** — reusing one connection per client drops
//!   the per-request connect/teardown, so keep-alive req/s should be at
//!   least close req/s everywhere, most visibly at 16 clients.
//!
//! Tail latency (p50/p95/p99) is reported per cell because the batcher's
//! deadline handling is exactly what the serving runtime is about.
//!
//! Run: `cargo bench --bench bench_serve`
//! Emits a table to stdout and a JSON record per cell to
//! `results/bench_serve.json` for the CI regression guard.

use ials::bench_harness::Table;
use ials::runtime::checkpoint::CheckpointManager;
use ials::serve::{json, Server, ServeOptions};
use ials::testkit::fault::read_one_response;
use ials::util::state::StateWriter;
use ials::util::Pcg32;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

const OBS: usize = 32;
const HID: usize = 64;
const ACT: usize = 8;
const LEARNERS: usize = 2;

const MODE_SWEEP: [&str; 2] = ["close", "keepalive"];
const CLIENT_SWEEP: [usize; 3] = [1, 4, 16];
const WINDOW_SWEEP_MS: [u64; 2] = [0, 2];
const REQUESTS_PER_CLIENT: usize = 200;
const WARMUP_PER_CLIENT: usize = 20;

struct Cell {
    mode: &'static str,
    clients: usize,
    batch_window_ms: u64,
    requests_per_sec: f64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
}

// ---------------------------------------------------------------------------
// Checkpoint fabrication (the exact `write_checkpoint` payload layout)
// ---------------------------------------------------------------------------

fn policy_tensors(seed: u64) -> Vec<(String, Vec<f32>)> {
    let mut rng = Pcg32::seeded(seed);
    let mut tensor = |name: &str, n: usize| {
        let vals: Vec<f32> =
            (0..n).map(|_| (rng.next_u32() as f32 / u32::MAX as f32) - 0.5).collect();
        (name.to_string(), vals)
    };
    vec![
        tensor("w1", OBS * HID),
        tensor("b1", HID),
        tensor("w2", HID * HID),
        tensor("b2", HID),
        tensor("w_pi", HID * ACT),
        tensor("b_pi", ACT),
        tensor("w_v", HID),
        tensor("b_v", 1),
    ]
}

fn checkpoint_payload() -> Vec<u8> {
    let mut w = StateWriter::new();
    w.str("ials"); // domain
    w.str("ials"); // simulator
    w.str("policy"); // policy model
    w.usize(LEARNERS);
    w.usize(8); // num_envs
    w.usize(16); // rollout_len
    w.usize(1024); // total_steps
    w.usize(256); // eval_every
    w.usize(3); // rounds_done
    for l in 0..LEARNERS {
        w.u64(100 + l as u64);
        let tensors = policy_tensors(7000 + l as u64);
        w.usize(tensors.len());
        for (name, vals) in &tensors {
            w.str(name);
            w.f32s(vals);
        }
        w.bytes(&[1, 2, 3]); // opaque loop state (serving skips it)
        w.bytes(&[4, 5]); // opaque env state (serving skips it)
    }
    w.into_bytes()
}

fn checkpoint_dir() -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ials_bench_serve_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    CheckpointManager::new(&dir, 4).save(1, &checkpoint_payload()).expect("save checkpoint");
    dir
}

// ---------------------------------------------------------------------------
// Client fan-out
// ---------------------------------------------------------------------------

/// One canonical act request per learner, prebuilt so client threads only
/// write bytes and read the reply. `close` decides the connection mode:
/// `Connection: close` (one connection per request) or the HTTP/1.1
/// keep-alive default.
fn request_bytes(learner: usize, close: bool) -> Vec<u8> {
    let obs: Vec<f32> = (0..OBS).map(|i| i as f32 * 0.01 - 0.15).collect();
    let body = format!("{{\"obs\":{}}}", json::nums(&obs));
    let connection = if close { "connection: close\r\n" } else { "" };
    format!(
        "POST /v1/runs/0/learners/{learner}/act HTTP/1.1\r\n{connection}content-length: {}\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

fn exchange(addr: SocketAddr, raw: &[u8]) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(15))).unwrap();
    s.write_all(raw).unwrap();
    let mut out = Vec::new();
    let _ = s.read_to_end(&mut out);
    String::from_utf8_lossy(&out).to_string()
}

/// `clients` threads × `reqs` one-connection-per-request exchanges each;
/// returns every request's wall-clock latency in seconds.
fn drive_close(addr: SocketAddr, clients: usize, reqs: usize) -> Vec<f64> {
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            std::thread::spawn(move || {
                let raw = request_bytes(c % LEARNERS, true);
                let mut lat = Vec::with_capacity(reqs);
                for _ in 0..reqs {
                    let t0 = Instant::now();
                    let resp = exchange(addr, &raw);
                    lat.push(t0.elapsed().as_secs_f64());
                    assert!(
                        resp.starts_with("HTTP/1.1 200"),
                        "bench request failed: {}",
                        &resp[..resp.len().min(120)]
                    );
                }
                lat
            })
        })
        .collect();
    handles.into_iter().flat_map(|h| h.join().expect("client thread")).collect()
}

/// `clients` threads, each holding ONE keep-alive connection for all its
/// `reqs` requests (responses framed by content-length).
fn drive_keepalive(addr: SocketAddr, clients: usize, reqs: usize) -> Vec<f64> {
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            std::thread::spawn(move || {
                let raw = request_bytes(c % LEARNERS, false);
                let stream = TcpStream::connect(addr).expect("connect");
                stream.set_read_timeout(Some(Duration::from_secs(15))).unwrap();
                let mut reader = std::io::BufReader::new(&stream);
                let mut lat = Vec::with_capacity(reqs);
                for _ in 0..reqs {
                    let t0 = Instant::now();
                    let mut w = &stream;
                    w.write_all(&raw).expect("keep-alive write");
                    let (head, _body) = read_one_response(&mut reader).expect("keep-alive read");
                    lat.push(t0.elapsed().as_secs_f64());
                    assert!(head.starts_with("HTTP/1.1 200"), "bench request failed: {head}");
                }
                lat
            })
        })
        .collect();
    handles.into_iter().flat_map(|h| h.join().expect("client thread")).collect()
}

fn drive(addr: SocketAddr, mode: &str, clients: usize, reqs: usize) -> Vec<f64> {
    match mode {
        "close" => drive_close(addr, clients, reqs),
        "keepalive" => drive_keepalive(addr, clients, reqs),
        other => unreachable!("unknown mode {other}"),
    }
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx]
}

fn measure(dir: &Path, mode: &'static str, clients: usize, batch_window_ms: u64) -> Cell {
    let opts = ServeOptions {
        port: 0,
        batch_window: Duration::from_millis(batch_window_ms),
        max_batch: 64,
        queue_capacity: 1024,
        workers: 8,
        read_timeout: Duration::from_secs(5),
        write_timeout: Duration::from_secs(5),
        request_timeout: Duration::from_secs(10),
        max_body_bytes: 1 << 20,
        max_requests_per_conn: 100_000,
        idle_timeout: Duration::from_secs(5),
        engine_stall: None,
        inject_panic: false,
    };
    let server = Server::spawn(&[dir.to_path_buf()], opts).expect("spawn server");
    let addr = server.addr();

    drive(addr, mode, clients, WARMUP_PER_CLIENT); // warmup
    let t0 = Instant::now();
    let mut lat = drive(addr, mode, clients, REQUESTS_PER_CLIENT);
    let elapsed = t0.elapsed().as_secs_f64();

    server.begin_shutdown();
    server.join().expect("server join");

    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let total = (clients * REQUESTS_PER_CLIENT) as f64;
    let rps = total / elapsed;
    println!(
        "bench serve/{mode}/c{clients}/w{batch_window_ms}ms: {rps:.0} req/s  p50 {:.3} ms  \
         p99 {:.3} ms",
        percentile(&lat, 0.50) * 1e3,
        percentile(&lat, 0.99) * 1e3,
    );
    Cell {
        mode,
        clients,
        batch_window_ms,
        requests_per_sec: rps,
        p50_ms: percentile(&lat, 0.50) * 1e3,
        p95_ms: percentile(&lat, 0.95) * 1e3,
        p99_ms: percentile(&lat, 0.99) * 1e3,
    }
}

fn main() {
    let dir = checkpoint_dir();
    let mut cells: Vec<Cell> = Vec::new();
    for &mode in &MODE_SWEEP {
        for &w in &WINDOW_SWEEP_MS {
            for &c in &CLIENT_SWEEP {
                cells.push(measure(&dir, mode, c, w));
            }
        }
    }

    let mut table = Table::new(
        "policy-inference serving (end-to-end act requests/sec over loopback TCP)",
        &["mode", "clients", "window ms", "req/s", "p50 ms", "p95 ms", "p99 ms"],
    );
    for c in &cells {
        table.row(&[
            c.mode.to_string(),
            c.clients.to_string(),
            c.batch_window_ms.to_string(),
            format!("{:.0}", c.requests_per_sec),
            format!("{:.3}", c.p50_ms),
            format!("{:.3}", c.p95_ms),
            format!("{:.3}", c.p99_ms),
        ]);
    }
    table.print();

    // The headline comparison: keep-alive vs close at the top of the
    // client sweep (connection reuse should never lose).
    let top = *CLIENT_SWEEP.last().unwrap();
    for &w in &WINDOW_SWEEP_MS {
        let find = |m: &str| {
            cells
                .iter()
                .find(|c| c.mode == m && c.clients == top && c.batch_window_ms == w)
                .expect("swept cell")
        };
        let (close, ka) = (find("close"), find("keepalive"));
        println!(
            "keep-alive vs close at {top} clients, window {w} ms: {:.0} vs {:.0} req/s ({:.2}x)",
            ka.requests_per_sec,
            close.requests_per_sec,
            ka.requests_per_sec / close.requests_per_sec
        );
    }

    // Hand-rolled JSON (no serde in the offline crate set).
    let mut json = String::from("[\n");
    for (i, c) in cells.iter().enumerate() {
        json.push_str(&format!(
            "  {{\"op\": \"serve_act\", \"mode\": \"{}\", \"clients\": {}, \
             \"batch_window_ms\": {}, \"learners\": {}, \"requests_per_sec\": {:.1}, \
             \"p50_ms\": {:.3}, \"p95_ms\": {:.3}, \"p99_ms\": {:.3}, \"backend\": \"native\"}}{}\n",
            c.mode,
            c.clients,
            c.batch_window_ms,
            LEARNERS,
            c.requests_per_sec,
            c.p50_ms,
            c.p95_ms,
            c.p99_ms,
            if i + 1 == cells.len() { "" } else { "," }
        ));
    }
    json.push_str("]\n");
    println!("{json}");
    if let Err(e) = std::fs::create_dir_all("results")
        .and_then(|_| std::fs::File::create("results/bench_serve.json"))
        .and_then(|mut f| f.write_all(json.as_bytes()))
    {
        eprintln!("could not write results/bench_serve.json: {e}");
    }
    std::fs::remove_dir_all(&dir).ok();
}
