//! NN forward throughput on the native CPU backend: policy MLP and AIP
//! (FNN + GRU step) forwards across batch sizes — the NN half of the IALS
//! step loop, tracked alongside the sim half (`bench_parallel_scaling`).
//!
//! Run: `cargo bench --bench bench_nn_forward`
//! Emits a table to stdout and a JSON record per cell to
//! `results/bench_nn_forward.json` for the bench trajectory.

use ials::bench_harness::{Bench, Table};
use ials::influence::{InfluencePredictor, NeuralAip};
use ials::rl::Policy;
use ials::runtime::{Runtime, SynthGeometry};
use std::io::Write;
use std::rc::Rc;

const BATCH_SWEEP: [usize; 5] = [1, 16, 64, 256, 1024];
/// Forward calls per timed rep (amortizes timer overhead at small batch).
const CALLS_PER_REP: usize = 64;

struct Cell {
    model: &'static str,
    batch: usize,
    rows_per_sec: f64,
    us_per_call: f64,
}

fn native_runtime(batch: usize) -> Rc<Runtime> {
    Rc::new(Runtime::native(&SynthGeometry {
        rollout_b: batch,
        ..SynthGeometry::default()
    }))
}

fn bench_policy(batch: usize, cells: &mut Vec<Cell>) {
    let rt = native_runtime(batch);
    let mut policy = Policy::new(rt, "policy_traffic", batch).expect("policy");
    let obs = vec![0.25f32; batch * policy.obs_dim];
    let mut logits = vec![0.0f32; batch * policy.act_dim];
    let mut values = vec![0.0f32; batch];
    let label = format!("policy_traffic/B{batch}");
    let r = Bench::new(&label).warmup(3).reps(20).run((CALLS_PER_REP * batch) as f64, || {
        for _ in 0..CALLS_PER_REP {
            policy.forward_into(&obs, &mut logits, &mut values).unwrap();
        }
    });
    cells.push(Cell {
        model: "policy_traffic",
        batch,
        rows_per_sec: r.throughput(),
        us_per_call: r.summary.mean * 1e6 / CALLS_PER_REP as f64,
    });
}

fn bench_aip(
    model: &'static str,
    dset_dim: usize,
    u_dim: usize,
    batch: usize,
    cells: &mut Vec<Cell>,
) {
    let rt = native_runtime(batch);
    let mut aip = NeuralAip::new(rt, model, batch).expect("aip");
    let dsets = vec![0.5f32; batch * dset_dim];
    let mut probs = vec![0.0f32; batch * u_dim];
    let label = format!("{model}/B{batch}");
    let r = Bench::new(&label).warmup(3).reps(20).run((CALLS_PER_REP * batch) as f64, || {
        for _ in 0..CALLS_PER_REP {
            aip.predict(&dsets, &mut probs).unwrap();
        }
    });
    cells.push(Cell {
        model,
        batch,
        rows_per_sec: r.throughput(),
        us_per_call: r.summary.mean * 1e6 / CALLS_PER_REP as f64,
    });
}

fn main() {
    let mut cells: Vec<Cell> = Vec::new();
    for &b in &BATCH_SWEEP {
        bench_policy(b, &mut cells);
        bench_aip("aip_traffic", 40, 4, b, &mut cells);
        bench_aip("aip_warehouse", 24, 12, b, &mut cells);
    }

    let mut table = Table::new(
        "native NN forward throughput (rows/sec; policy MLP + AIP FNN + GRU step)",
        &["model", "B", "rows/s", "µs/call"],
    );
    for c in &cells {
        table.row(&[
            c.model.into(),
            c.batch.to_string(),
            format!("{:.0}", c.rows_per_sec),
            format!("{:.1}", c.us_per_call),
        ]);
    }
    table.print();

    // Hand-rolled JSON (no serde in the offline crate set).
    let mut json = String::from("[\n");
    for (i, c) in cells.iter().enumerate() {
        json.push_str(&format!(
            "  {{\"model\": \"{}\", \"batch\": {}, \"rows_per_sec\": {:.1}, \
             \"us_per_call\": {:.2}, \"backend\": \"native\"}}{}\n",
            c.model,
            c.batch,
            c.rows_per_sec,
            c.us_per_call,
            if i + 1 == cells.len() { "" } else { "," }
        ));
    }
    json.push_str("]\n");
    println!("{json}");
    if let Err(e) = std::fs::create_dir_all("results")
        .and_then(|_| std::fs::File::create("results/bench_nn_forward.json"))
        .and_then(|mut f| f.write_all(json.as_bytes()))
    {
        eprintln!("could not write results/bench_nn_forward.json: {e}");
    }
}
