//! End-to-end IALS rollout throughput with the **real native NN in the
//! loop** — observe → batched policy forward → action sampling → env step,
//! i.e. the PPO collection hot loop (`bench_parallel_scaling` only
//! measures fixed-marginal-AIP sim throughput). Sweeps `num_workers × B`
//! for the fig3 traffic IALS (FNN AIP) and the fig5 warehouse GRU-IALS
//! (frame-stacked, recurrent AIP), comparing the **fused** single-dispatch
//! step pipeline against the PR 3 **sandwich** (parallel gather →
//! coordinator-batched AIP call → parallel step). Both pipelines are
//! bitwise identical at the same seed; only wall-clock may differ.
//!
//! Run: `cargo bench --bench bench_rollout`
//! Emits a table to stdout and a JSON record (one object per cell) to
//! `results/bench_rollout.json` for the bench trajectory / CI regression
//! guard.

use ials::bench_harness::{Bench, Table};
use ials::config::{TrafficConfig, WarehouseConfig};
use ials::core::{FrameStackVec, VecEnv};
use ials::ials::IalsVecEnv;
use ials::influence::NeuralAip;
use ials::rl::Policy;
use ials::runtime::{Runtime, SynthGeometry};
use ials::sim::traffic::TrafficLocalEnv;
use ials::sim::warehouse::WarehouseLocalEnv;
use ials::util::Pcg32;
use std::io::Write;
use std::rc::Rc;

const WORKER_SWEEP: [usize; 3] = [1, 2, 4];
const BATCH_SWEEP: [usize; 2] = [256, 1024];
const WH_STACK: usize = 8;

struct Cell {
    domain: &'static str,
    batch: usize,
    workers: usize,
    pipeline: &'static str,
    steps_per_sec: f64,
    speedup_vs_sandwich: f64,
}

/// One rollout-style measurement: the PPO collection loop minus the buffer
/// writes (observe, batched forward, sample, step), all scratch reused.
fn measure(env: &mut dyn VecEnv, policy: &mut Policy, vec_steps: usize, label: &str) -> f64 {
    let b = env.num_envs();
    assert_eq!(env.obs_dim(), policy.obs_dim);
    let mut rng = Pcg32::seeded(1);
    let mut obs = vec![0.0f32; b * policy.obs_dim];
    let mut logits = vec![0.0f32; b * policy.act_dim];
    let mut values = vec![0.0f32; b];
    let mut log_probs = vec![0.0f32; b];
    let mut actions = vec![0usize; b];
    let mut rewards = vec![0.0f32; b];
    let mut dones = vec![false; b];
    env.reset_all(7);
    let r = Bench::new(label).warmup(1).reps(3).run((vec_steps * b) as f64, || {
        for _ in 0..vec_steps {
            env.observe_all(&mut obs);
            policy.forward_into(&obs, &mut logits, &mut values).expect("policy forward");
            policy.sample_actions(&logits, &mut rng, &mut actions, &mut log_probs);
            env.step_all(&actions, &mut rewards, &mut dones);
        }
    });
    r.throughput()
}

/// Fig3 traffic IALS: FNN AIP + policy_traffic, `w` sim workers sharing
/// the pool with `w` NN workers (the sandwich's batched calls get the
/// same parallelism the fused pipeline folds into its dispatch).
fn traffic_rate(b: usize, w: usize, fused: bool, vec_steps: usize, label: &str) -> f64 {
    let geom = SynthGeometry { rollout_b: b, ..SynthGeometry::default() };
    let rt = Rc::new(Runtime::native_parallel(&geom, w));
    let cfg = TrafficConfig::default();
    let envs: Vec<TrafficLocalEnv> = (0..b).map(|_| TrafficLocalEnv::new(&cfg)).collect();
    let aip = NeuralAip::new(rt.clone(), "aip_traffic", b).expect("FNN AIP");
    let mut env = IalsVecEnv::with_workers(envs, Box::new(aip), w);
    env.set_fused(fused);
    let mut policy = Policy::new(rt, "policy_traffic", b).expect("policy");
    measure(&mut env, &mut policy, vec_steps, label)
}

/// Fig5 warehouse GRU-IALS: recurrent AIP + 8-frame stacking +
/// policy_warehouse, same worker layout as traffic.
fn warehouse_rate(b: usize, w: usize, fused: bool, vec_steps: usize, label: &str) -> f64 {
    let geom = SynthGeometry { rollout_b: b, ..SynthGeometry::default() };
    let rt = Rc::new(Runtime::native_parallel(&geom, w));
    let cfg = WarehouseConfig::default();
    let envs: Vec<WarehouseLocalEnv> = (0..b).map(|_| WarehouseLocalEnv::new(&cfg)).collect();
    let aip = NeuralAip::new(rt.clone(), "aip_warehouse", b).expect("GRU AIP");
    let mut inner = IalsVecEnv::with_workers(envs, Box::new(aip), w);
    inner.set_fused(fused);
    let mut env = FrameStackVec::new(inner, WH_STACK);
    let mut policy = Policy::new(rt, "policy_warehouse", b).expect("policy");
    measure(&mut env, &mut policy, vec_steps, label)
}

fn sweep(domain: &'static str, cells: &mut Vec<Cell>) {
    for &b in &BATCH_SWEEP {
        // Keep total work roughly constant across batch sizes.
        let vec_steps = (8192 / b).max(8);
        for &w in &WORKER_SWEEP {
            let mut rates = [0.0f64; 2];
            for (k, pipeline) in ["sandwich", "fused"].into_iter().enumerate() {
                let label = format!("{domain}/B{b}/w{w}/{pipeline}");
                let fused = pipeline == "fused";
                rates[k] = match domain {
                    "traffic" => traffic_rate(b, w, fused, vec_steps, &label),
                    _ => warehouse_rate(b, w, fused, vec_steps, &label),
                };
                cells.push(Cell {
                    domain,
                    batch: b,
                    workers: w,
                    pipeline,
                    steps_per_sec: rates[k],
                    speedup_vs_sandwich: rates[k] / rates[0].max(1e-12),
                });
            }
        }
    }
}

fn main() {
    let mut cells: Vec<Cell> = Vec::new();
    sweep("traffic", &mut cells);
    sweep("warehouse", &mut cells);

    let mut table = Table::new(
        "end-to-end IALS rollout (steps/sec; real native NN in the loop)",
        &["domain", "B", "workers", "pipeline", "steps/s", "vs sandwich"],
    );
    for c in &cells {
        table.row(&[
            c.domain.into(),
            c.batch.to_string(),
            c.workers.to_string(),
            c.pipeline.into(),
            format!("{:.0}", c.steps_per_sec),
            format!("{:.2}x", c.speedup_vs_sandwich),
        ]);
    }
    table.print();

    // Hand-rolled JSON (no serde in the offline crate set).
    let mut json = String::from("[\n");
    for (i, c) in cells.iter().enumerate() {
        json.push_str(&format!(
            "  {{\"domain\": \"{}\", \"batch\": {}, \"num_workers\": {}, \
             \"pipeline\": \"{}\", \"steps_per_sec\": {:.1}, \
             \"speedup_vs_sandwich\": {:.3}}}{}\n",
            c.domain,
            c.batch,
            c.workers,
            c.pipeline,
            c.steps_per_sec,
            c.speedup_vs_sandwich,
            if i + 1 == cells.len() { "" } else { "," }
        ));
    }
    json.push_str("]\n");
    println!("{json}");
    if let Err(e) = std::fs::create_dir_all("results")
        .and_then(|_| std::fs::File::create("results/bench_rollout.json"))
        .and_then(|mut f| f.write_all(json.as_bytes()))
    {
        eprintln!("could not write results/bench_rollout.json: {e}");
    }

    // Headline for the acceptance criterion: traffic, B=1024, 4 workers,
    // fused vs sandwich.
    if let Some(c) = cells.iter().find(|c| {
        c.domain == "traffic" && c.batch == 1024 && c.workers == 4 && c.pipeline == "fused"
    }) {
        println!(
            "headline: traffic B=1024 workers=4 fused -> {:.2}x vs sandwich ({:.0} steps/s)",
            c.speedup_vs_sandwich, c.steps_per_sec
        );
    }
}
