//! Microbenchmarks of every compiled artifact on the hot path: policy
//! forwards, AIP forwards (FNN + Pallas-fused GRU step), and the training
//! updates. These are the fixed NN overheads the IALS must amortize.

use ials::bench_harness::{Bench, Table};
use ials::runtime::{DataArg, Runtime};

fn main() {
    let rt = Runtime::load_or_native("artifacts").expect("runtime");
    let title = format!("artifact call latency ({} backend)", rt.backend_kind());
    let mut table = Table::new(&title, &["artifact", "mean µs", "p95 µs"]);

    let mut add = |name: &str, data: &[DataArg<'_>]| {
        let model = rt.manifest.artifact(name).unwrap().model.clone();
        let mut store = rt.load_store(&model).unwrap();
        let r = Bench::new(name).warmup(20).reps(200).run(1.0, || {
            rt.call(name, &mut store, data).unwrap();
        });
        table.row(&[
            name.into(),
            format!("{:.1}", r.summary.mean * 1e6),
            format!("{:.1}", r.summary.p95 * 1e6),
        ]);
    };

    let obs16 = vec![0.3f32; 16 * 42];
    let obs1 = vec![0.3f32; 42];
    add("policy_traffic_fwd_b16", &[DataArg::F32(&obs16)]);
    add("policy_traffic_fwd_b1", &[DataArg::F32(&obs1)]);

    let d16 = vec![1.0f32; 16 * 40];
    add("aip_traffic_fwd_b16", &[DataArg::F32(&d16)]);

    let h16 = vec![0.0f32; 16 * 64];
    let wd16 = vec![0.5f32; 16 * 24];
    add("aip_warehouse_step_b16", &[DataArg::F32(&h16), DataArg::F32(&wd16)]);

    let wobs16 = vec![0.1f32; 16 * 296];
    add("policy_warehouse_fwd_b16", &[DataArg::F32(&wobs16)]);

    // training artifacts
    let lr = [1e-3f32];
    let ad = vec![0.5f32; 256 * 40];
    let ay = vec![0.0f32; 256 * 4];
    add("aip_traffic_update", &[DataArg::F32(&lr), DataArg::F32(&ad), DataArg::F32(&ay)]);
    let seqs = vec![0.5f32; 16 * 32 * 24];
    let tgts = vec![0.0f32; 16 * 32 * 12];
    add("aip_warehouse_update", &[DataArg::F32(&lr), DataArg::F32(&seqs), DataArg::F32(&tgts)]);
    let pobs = vec![0.1f32; 256 * 42];
    let pact = vec![0i32; 256];
    let padv = vec![0.1f32; 256];
    let pret = vec![0.1f32; 256];
    let plog = vec![-0.7f32; 256];
    let h: Vec<[f32; 1]> = vec![[3e-4], [0.2], [0.5], [0.01], [0.5]];
    add(
        "policy_traffic_update",
        &[
            DataArg::F32(&h[0]),
            DataArg::F32(&h[1]),
            DataArg::F32(&h[2]),
            DataArg::F32(&h[3]),
            DataArg::F32(&h[4]),
            DataArg::F32(&pobs),
            DataArg::I32(&pact),
            DataArg::F32(&padv),
            DataArg::F32(&pret),
            DataArg::F32(&plog),
        ],
    );

    table.print();
}
