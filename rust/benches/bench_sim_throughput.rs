//! Headline systems table: raw steps/sec of GS vs LS vs IALS (LS + neural
//! AIP) for both domains — the mechanism behind every wall-clock result in
//! the paper. Run via `cargo bench --bench bench_sim_throughput`.

use ials::bench_harness::{Bench, Table};
use ials::config::ExperimentConfig;
use ials::config::SimulatorKind;
use ials::coordinator::experiment::{make_train_env, prepare_predictor};
use ials::runtime::Runtime;
use ials::util::Pcg32;
use std::rc::Rc;

fn steps_per_sec(env: &mut dyn ials::core::VecEnv, vec_steps: usize, label: &str) -> f64 {
    let b = env.num_envs();
    let mut rng = Pcg32::seeded(1);
    let mut rewards = vec![0.0f32; b];
    let mut dones = vec![false; b];
    let mut actions = vec![0usize; b];
    env.reset_all(7);
    let na = env.num_actions();
    let r = Bench::new(label).warmup(1).reps(5).run((vec_steps * b) as f64, || {
        for _ in 0..vec_steps {
            for a in actions.iter_mut() {
                *a = rng.below(na);
            }
            env.step_all(&actions, &mut rewards, &mut dones);
        }
    });
    r.throughput()
}

fn main() {
    let rt = Rc::new(Runtime::load_or_native("artifacts").expect("runtime"));
    let mut table = Table::new(
        "simulator throughput (env-steps/sec, batch 16, random policy)",
        &["domain", "GS", "LS+AIP (IALS)", "LS+fixed", "IALS/GS speedup"],
    );

    for domain in ["traffic", "warehouse"] {
        let mut cfg = ExperimentConfig::default();
        cfg.domain = ials::config::DomainKind::parse(domain).unwrap();
        cfg.aip.dataset_size = 4096;
        cfg.aip.train_epochs = 1;
        if domain == "warehouse" {
            cfg.warehouse.frame_stack = 1; // raw sim rate, no stacking
        }

        // GS
        let mut gs = make_train_env(&cfg, None);
        let gs_rate = steps_per_sec(gs.as_mut(), 300, &format!("{domain}/gs"));

        // IALS (trained neural AIP — AIP training excluded; this measures
        // the simulation loop only)
        cfg.simulator = SimulatorKind::Ials;
        let prep = prepare_predictor(&rt, &cfg, 1, cfg.ppo.num_envs).unwrap();
        let mut ials_env = make_train_env(&cfg, prep.predictor);
        let ials_rate = steps_per_sec(ials_env.as_mut(), 300, &format!("{domain}/ials"));

        // LS + fixed marginal (isolates the PJRT AIP-call overhead)
        cfg.simulator = SimulatorKind::FixedIals;
        cfg.aip.fixed_p = 0.1;
        let prep = prepare_predictor(&rt, &cfg, 1, cfg.ppo.num_envs).unwrap();
        let mut fixed_env = make_train_env(&cfg, prep.predictor);
        let fixed_rate = steps_per_sec(fixed_env.as_mut(), 300, &format!("{domain}/fixed"));

        table.row(&[
            domain.into(),
            format!("{gs_rate:.0}"),
            format!("{ials_rate:.0}"),
            format!("{fixed_rate:.0}"),
            format!("{:.2}x", ials_rate / gs_rate),
        ]);
    }
    table.print();

    // Scalability sweep (the paper's title claim): GS cost grows with the
    // size of the networked system; the IALS cost is constant, so the
    // speedup scales with the city.
    let mut scale = Table::new(
        "traffic scalability: speedup vs city size (IALS cost is city-size independent)",
        &["grid (intersections)", "GS steps/s", "IALS steps/s", "speedup"],
    );
    for grid in [5usize, 7, 9, 13] {
        let mut cfg = ExperimentConfig::default();
        cfg.traffic.grid = grid;
        cfg.aip.dataset_size = 4096;
        cfg.aip.train_epochs = 1;
        let mut gs = make_train_env(&cfg, None);
        let gs_rate = steps_per_sec(gs.as_mut(), 150, &format!("traffic/gs/grid{grid}"));
        cfg.simulator = SimulatorKind::Ials;
        let prep = prepare_predictor(&rt, &cfg, 1, cfg.ppo.num_envs).unwrap();
        let mut ials_env = make_train_env(&cfg, prep.predictor);
        let ials_rate = steps_per_sec(ials_env.as_mut(), 150, &format!("traffic/ials/grid{grid}"));
        scale.row(&[
            format!("{grid}x{grid} ({})", grid * grid),
            format!("{gs_rate:.0}"),
            format!("{ials_rate:.0}"),
            format!("{:.2}x", ials_rate / gs_rate),
        ]);
    }
    scale.print();
}
