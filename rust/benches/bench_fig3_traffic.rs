//! Figure 3 (and, with `IALS_BENCH_INTERSECTION=2`, Figure 10): traffic
//! learning curves + runtime bars + AIP CE bars, at a bench-sized budget.
//! The full-scale run is `repro figure --name fig3 --config configs/fig3.toml`.

use ials::config::ExperimentConfig;
use ials::coordinator::run_figure;
use ials::runtime::Runtime;
use std::rc::Rc;

fn main() {
    ials::util::logger::init();
    let rt = Rc::new(Runtime::load_or_native("artifacts").expect("runtime"));
    let mut base = ExperimentConfig::default();
    base.seeds = vec![1];
    base.ppo.total_steps = 16_384;
    base.eval_every = 8_192;
    base.eval_episodes = 2;
    base.aip.dataset_size = 20_000;
    base.aip.train_epochs = 4;
    base.results_dir = "results/bench".into();
    let fig = if std::env::var("IALS_BENCH_INTERSECTION").as_deref() == Ok("2") {
        "fig10"
    } else {
        "fig3"
    };
    run_figure(&rt, fig, &base).expect("figure run failed");
}
