//! Multi-learner round-robin throughput: K IALS learners (fig3 traffic
//! geometry, real native NN in the loop) interleaved over the one
//! process-shared compute pool, sweeping `learners × workers` (the sim
//! and NN halves share the worker count, as the fig3 config does). The
//! interesting ratio is aggregate env-steps/sec vs the single-learner
//! run at the same worker count: K policies per wall-clock run, ideally
//! at K× the single-learner cost or better (shared pool, shared engine,
//! shared AIP dataset — only the parameters are per learner).
//!
//! Run: `cargo bench --bench bench_multi_learner`
//! Emits a table to stdout and a JSON record per cell to
//! `results/bench_multi_learner.json` for the CI regression guard.

use ials::bench_harness::{Bench, Table};
use ials::config::{BackendKind, DomainKind, ExperimentConfig, SimulatorKind};
use ials::coordinator::MultiLearnerRun;
use ials::runtime::Runtime;
use std::io::Write;
use std::rc::Rc;

const LEARNER_SWEEP: [usize; 3] = [1, 2, 4];
const WORKER_SWEEP: [usize; 3] = [1, 2, 4];

struct Cell {
    learners: usize,
    workers: usize,
    steps_per_sec: f64,
    per_learner_steps_per_sec: f64,
    throughput_vs_one_learner: f64,
}

/// Fig3 traffic IALS geometry, scaled for a bench: full rollout shape,
/// small shared dataset, evaluations pushed out of the timed rounds.
fn bench_cfg(learners: usize, workers: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "bench-multi".into();
    cfg.domain = DomainKind::Traffic;
    cfg.simulator = SimulatorKind::Ials;
    cfg.num_learners = learners;
    cfg.eval_every = usize::MAX / 2;
    cfg.eval_episodes = 1;
    cfg.ppo.num_envs = 16;
    cfg.ppo.rollout_len = 128;
    cfg.ppo.minibatch = 256;
    cfg.ppo.total_steps = usize::MAX / 2;
    cfg.ppo.num_workers = workers;
    cfg.aip.dataset_size = 4000;
    cfg.aip.eval_size = 1000;
    cfg.aip.train_epochs = 1;
    cfg.runtime.backend = BackendKind::Native;
    cfg.runtime.nn_workers = workers;
    cfg.validate().expect("bench config");
    cfg
}

/// Aggregate env-steps/sec of the round-robin loop (collection + PPO
/// update for every learner, one full round per rep).
fn measure(learners: usize, workers: usize) -> f64 {
    let cfg = bench_cfg(learners, workers);
    let rt = Rc::new(Runtime::from_config(&cfg).expect("runtime"));
    let mut run = MultiLearnerRun::build(&rt, &cfg, 7).expect("multi-learner build");
    run.start().expect("start");
    let steps_per_round = run.steps_per_round();
    let label = format!("traffic/L{learners}/w{workers}");
    let r = Bench::new(&label).warmup(1).reps(2).run(steps_per_round as f64, || {
        run.advance_round().expect("advance_round");
    });
    r.throughput()
}

fn main() {
    let mut cells: Vec<Cell> = Vec::new();
    for &w in &WORKER_SWEEP {
        let mut base = 0.0f64;
        for &l in &LEARNER_SWEEP {
            let agg = measure(l, w);
            if l == 1 {
                base = agg;
            }
            cells.push(Cell {
                learners: l,
                workers: w,
                steps_per_sec: agg,
                per_learner_steps_per_sec: agg / l as f64,
                throughput_vs_one_learner: agg / base.max(1e-12),
            });
        }
    }

    let mut table = Table::new(
        "multi-learner round-robin (aggregate env steps/sec; fig3 traffic IALS)",
        &["learners", "workers", "steps/s", "per-learner", "vs 1 learner"],
    );
    for c in &cells {
        table.row(&[
            c.learners.to_string(),
            c.workers.to_string(),
            format!("{:.0}", c.steps_per_sec),
            format!("{:.0}", c.per_learner_steps_per_sec),
            format!("{:.2}x", c.throughput_vs_one_learner),
        ]);
    }
    table.print();

    // Hand-rolled JSON (no serde in the offline crate set).
    let mut json = String::from("[\n");
    for (i, c) in cells.iter().enumerate() {
        json.push_str(&format!(
            "  {{\"domain\": \"traffic\", \"learners\": {}, \"num_workers\": {}, \
             \"nn_workers\": {}, \"steps_per_sec\": {:.1}, \
             \"per_learner_steps_per_sec\": {:.1}, \"throughput_vs_one_learner\": {:.3}, \
             \"backend\": \"native\"}}{}\n",
            c.learners,
            c.workers,
            c.workers,
            c.steps_per_sec,
            c.per_learner_steps_per_sec,
            c.throughput_vs_one_learner,
            if i + 1 == cells.len() { "" } else { "," }
        ));
    }
    json.push_str("]\n");
    println!("{json}");
    if let Err(e) = std::fs::create_dir_all("results")
        .and_then(|_| std::fs::File::create("results/bench_multi_learner.json"))
        .and_then(|mut f| f.write_all(json.as_bytes()))
    {
        eprintln!("could not write results/bench_multi_learner.json: {e}");
    }

    // Headline: 4 learners on 4 workers vs 1 learner on 4 workers.
    let base = cells.iter().find(|c| c.learners == 1 && c.workers == 4);
    let four = cells.iter().find(|c| c.learners == 4 && c.workers == 4);
    if let (Some(b), Some(f)) = (base, four) {
        println!(
            "headline: 4 learners w=4 -> {:.2}x aggregate throughput vs 1 learner \
             ({:.0} vs {:.0} steps/s)",
            f.steps_per_sec / b.steps_per_sec.max(1e-12),
            f.steps_per_sec,
            b.steps_per_sec
        );
    }
}
