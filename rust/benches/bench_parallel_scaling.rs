//! Parallel env-stepping scaling: steps/sec of the sharded IALS executor
//! over `num_workers x batch`, for both local-sim families. No artifacts
//! needed — the AIP is a fixed marginal, so this isolates pure simulator
//! throughput (the quantity the IALS speedup story rests on).
//!
//! Run: `cargo bench --bench bench_parallel_scaling`
//! Emits a table to stdout and a JSON record (one object per cell) to
//! `results/bench_parallel_scaling.json` for the bench trajectory.

use ials::bench_harness::{Bench, Table};
use ials::config::{TrafficConfig, WarehouseConfig};
use ials::core::VecEnv;
use ials::ials::IalsVecEnv;
use ials::influence::FixedMarginalAip;
use ials::sim::traffic::TrafficLocalEnv;
use ials::sim::warehouse::WarehouseLocalEnv;
use ials::util::Pcg32;
use std::io::Write;

const WORKER_SWEEP: [usize; 4] = [1, 2, 4, 8];
const BATCH_SWEEP: [usize; 3] = [64, 256, 1024];

struct Cell {
    domain: &'static str,
    batch: usize,
    workers: usize,
    steps_per_sec: f64,
    speedup_vs_serial: f64,
}

fn measure(env: &mut dyn VecEnv, vec_steps: usize, label: &str) -> f64 {
    let b = env.num_envs();
    let na = env.num_actions();
    let mut rng = Pcg32::seeded(1);
    let mut actions = vec![0usize; b];
    let mut rewards = vec![0.0f32; b];
    let mut dones = vec![false; b];
    env.reset_all(7);
    let r = Bench::new(label).warmup(1).reps(5).run((vec_steps * b) as f64, || {
        for _ in 0..vec_steps {
            for a in actions.iter_mut() {
                *a = rng.below(na);
            }
            env.step_all(&actions, &mut rewards, &mut dones);
        }
    });
    r.throughput()
}

fn traffic_env(b: usize, w: usize) -> IalsVecEnv<TrafficLocalEnv> {
    let cfg = TrafficConfig::default();
    let envs: Vec<TrafficLocalEnv> = (0..b).map(|_| TrafficLocalEnv::new(&cfg)).collect();
    IalsVecEnv::with_workers(
        envs,
        Box::new(FixedMarginalAip::constant(b, 4 * cfg.lane_len, 4, 0.25)),
        w,
    )
}

fn warehouse_env(b: usize, w: usize) -> IalsVecEnv<WarehouseLocalEnv> {
    let cfg = WarehouseConfig::default();
    let envs: Vec<WarehouseLocalEnv> = (0..b).map(|_| WarehouseLocalEnv::new(&cfg)).collect();
    IalsVecEnv::with_workers(envs, Box::new(FixedMarginalAip::constant(b, 24, 12, 0.15)), w)
}

fn sweep(domain: &'static str, cells: &mut Vec<Cell>) {
    for &b in &BATCH_SWEEP {
        // Keep total work roughly constant across batch sizes.
        let vec_steps = (32_768 / b).max(8);
        let mut serial_rate = 0.0f64;
        for &w in &WORKER_SWEEP {
            let label = format!("{domain}/B{b}/w{w}");
            let rate = match domain {
                "traffic" => measure(&mut traffic_env(b, w), vec_steps, &label),
                _ => measure(&mut warehouse_env(b, w), vec_steps, &label),
            };
            if w == 1 {
                serial_rate = rate;
            }
            cells.push(Cell {
                domain,
                batch: b,
                workers: w,
                steps_per_sec: rate,
                speedup_vs_serial: rate / serial_rate.max(1e-12),
            });
        }
    }
}

fn main() {
    let mut cells: Vec<Cell> = Vec::new();
    sweep("traffic", &mut cells);
    sweep("warehouse", &mut cells);

    let mut table = Table::new(
        "sharded IALS env stepping (steps/sec; fixed-marginal AIP, random policy)",
        &["domain", "B", "workers", "steps/s", "speedup vs w=1"],
    );
    for c in &cells {
        table.row(&[
            c.domain.into(),
            c.batch.to_string(),
            c.workers.to_string(),
            format!("{:.0}", c.steps_per_sec),
            format!("{:.2}x", c.speedup_vs_serial),
        ]);
    }
    table.print();

    // Hand-rolled JSON (no serde in the offline crate set).
    let mut json = String::from("[\n");
    for (i, c) in cells.iter().enumerate() {
        json.push_str(&format!(
            "  {{\"domain\": \"{}\", \"batch\": {}, \"num_workers\": {}, \
             \"steps_per_sec\": {:.1}, \"speedup_vs_serial\": {:.3}}}{}\n",
            c.domain,
            c.batch,
            c.workers,
            c.steps_per_sec,
            c.speedup_vs_serial,
            if i + 1 == cells.len() { "" } else { "," }
        ));
    }
    json.push_str("]\n");
    println!("{json}");
    if let Err(e) = std::fs::create_dir_all("results")
        .and_then(|_| std::fs::File::create("results/bench_parallel_scaling.json"))
        .and_then(|mut f| f.write_all(json.as_bytes()))
    {
        eprintln!("could not write results/bench_parallel_scaling.json: {e}");
    }

    // Headline number for the acceptance criterion: traffic, B=1024, w=4.
    if let Some(c) = cells
        .iter()
        .find(|c| c.domain == "traffic" && c.batch == 1024 && c.workers == 4)
    {
        println!(
            "headline: traffic B=1024 num_workers=4 -> {:.2}x vs serial ({:.0} steps/s)",
            c.speedup_vs_serial, c.steps_per_sec
        );
    }
}
