//! Training-phase throughput of the data-parallel native engine:
//! `nn_workers × minibatch` sweep over the fused whole-phase PPO update,
//! the FNN BCE step and the GRU BPTT step — the NN-training half of the
//! loop, tracked alongside the forward half (`bench_nn_forward`) and the
//! sim half (`bench_parallel_scaling`).
//!
//! Run: `cargo bench --bench bench_ppo_update`
//! Emits a table to stdout and a JSON record per cell to
//! `results/bench_ppo_update.json`. Acceptance target: ≥ 2× fused-PPO
//! throughput at `nn_workers = 4`, minibatch ≥ 512 vs `nn_workers = 1`.

use ials::bench_harness::{Bench, Table};
use ials::config::PpoConfig;
use ials::nn::ParamStore;
use ials::rl::Policy;
use ials::runtime::{DataArg, Runtime, SynthGeometry};
use ials::util::Pcg32;
use std::io::Write;
use std::rc::Rc;

const WORKER_SWEEP: [usize; 3] = [1, 2, 4];
const MB_SWEEP: [usize; 3] = [128, 512, 1024];

struct Cell {
    op: &'static str,
    minibatch: usize,
    nn_workers: usize,
    rows_per_sec: f64,
    ms_per_update: f64,
    speedup_vs_serial: f64,
}

fn push_cell(
    cells: &mut Vec<Cell>,
    op: &'static str,
    minibatch: usize,
    nn_workers: usize,
    rows_per_sec: f64,
    ms_per_update: f64,
) {
    let serial = cells
        .iter()
        .find(|c| c.op == op && c.minibatch == minibatch && c.nn_workers == 1)
        .map(|c| c.rows_per_sec)
        .unwrap_or(rows_per_sec);
    cells.push(Cell {
        op,
        minibatch,
        nn_workers,
        rows_per_sec,
        ms_per_update,
        speedup_vs_serial: rows_per_sec / serial.max(1e-12),
    });
}

fn runtime(geom: &SynthGeometry, workers: usize) -> Rc<Runtime> {
    Rc::new(if workers == 1 {
        Runtime::native(geom)
    } else {
        Runtime::native_parallel(geom, workers)
    })
}

/// Fused whole-phase PPO update: 2 epochs over `n = 2 * mb` rows (4
/// minibatch updates per call), rows/sec counts minibatch rows processed.
fn bench_ppo_fused(mb: usize, workers: usize, cells: &mut Vec<Cell>) {
    let geom = SynthGeometry {
        rollout_b: 8,
        rollout_t: mb / 4,
        ppo_epochs: 2,
        ppo_minibatch: mb,
        ..SynthGeometry::default()
    };
    let rt = runtime(&geom, workers);
    let n = 8 * (mb / 4);
    let cfg = PpoConfig {
        num_envs: 8,
        rollout_len: mb / 4,
        epochs: 2,
        minibatch: mb,
        ..PpoConfig::default()
    };
    let mut policy = Policy::new(rt, "policy_traffic", 8).expect("policy");
    let mut rng = Pcg32::seeded(3);
    let obs: Vec<f32> = (0..n * 42).map(|_| rng.f32() - 0.5).collect();
    let actions: Vec<i32> = (0..n).map(|_| rng.below(2) as i32).collect();
    let adv: Vec<f32> = (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect();
    let ret: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
    let logp = vec![(0.5f32).ln(); n];
    let mut order: Vec<usize> = (0..n).collect();
    let mut perm: Vec<i32> = Vec::with_capacity(2 * n);
    for _ in 0..2 {
        rng.shuffle(&mut order);
        perm.extend(order.iter().map(|&k| k as i32));
    }
    let rows_per_call = 2 * n; // epochs × n minibatch rows per fused call
    let label = format!("ppo_fused/mb{mb}/w{workers}");
    let r = Bench::new(&label).warmup(2).reps(10).run(rows_per_call as f64, || {
        policy
            .update_fused(&cfg, &perm, &obs, &actions, &adv, &ret, &logp)
            .expect("fused update");
    });
    let updates_per_call = (rows_per_call / mb) as f64;
    push_cell(
        cells,
        "ppo_fused",
        mb,
        workers,
        r.throughput(),
        r.summary.mean * 1e3 / updates_per_call,
    );
}

/// One FNN BCE Adam step at minibatch `mb` (traffic AIP geometry).
fn bench_fnn_bce(mb: usize, workers: usize, cells: &mut Vec<Cell>) {
    let geom = SynthGeometry { aip_batch: mb, ..SynthGeometry::default() };
    let rt = runtime(&geom, workers);
    let mut store: ParamStore = rt.load_store("aip_traffic").expect("store");
    let mut rng = Pcg32::seeded(5);
    let lr = [1e-3f32];
    let d: Vec<f32> = (0..mb * 40).map(|_| rng.f32()).collect();
    let y: Vec<f32> = (0..mb * 4).map(|_| f32::from(rng.bernoulli(0.2))).collect();
    let mut loss = [0.0f32; 1];
    let label = format!("fnn_bce/mb{mb}/w{workers}");
    let r = Bench::new(&label).warmup(2).reps(20).run(mb as f64, || {
        rt.call_into(
            "aip_traffic_update",
            &mut store,
            &[DataArg::F32(&lr), DataArg::F32(&d), DataArg::F32(&y)],
            &mut [loss.as_mut_slice()],
        )
        .expect("fnn update");
    });
    push_cell(cells, "fnn_bce", mb, workers, r.throughput(), r.summary.mean * 1e3);
}

/// One GRU BPTT Adam step over `seq_b = mb / 32` windows of length 32
/// (warehouse AIP geometry); rows/sec counts sequence steps (B × T).
fn bench_gru_bptt(mb: usize, workers: usize, cells: &mut Vec<Cell>) {
    let (seq_b, seq_t) = (mb / 32, 32usize);
    let geom = SynthGeometry { gru_seq_b: seq_b, gru_seq_t: seq_t, ..SynthGeometry::default() };
    let rt = runtime(&geom, workers);
    let mut store: ParamStore = rt.load_store("aip_warehouse").expect("store");
    let mut rng = Pcg32::seeded(7);
    let lr = [1e-3f32];
    let seqs: Vec<f32> = (0..seq_b * seq_t * 24).map(|_| rng.f32()).collect();
    let y: Vec<f32> = (0..seq_b * seq_t * 12).map(|_| f32::from(rng.bernoulli(0.15))).collect();
    let mut loss = [0.0f32; 1];
    let label = format!("gru_bptt/mb{mb}/w{workers}");
    let r = Bench::new(&label).warmup(2).reps(10).run((seq_b * seq_t) as f64, || {
        rt.call_into(
            "aip_warehouse_update",
            &mut store,
            &[DataArg::F32(&lr), DataArg::F32(&seqs), DataArg::F32(&y)],
            &mut [loss.as_mut_slice()],
        )
        .expect("gru update");
    });
    push_cell(cells, "gru_bptt", mb, workers, r.throughput(), r.summary.mean * 1e3);
}

fn main() {
    let mut cells: Vec<Cell> = Vec::new();
    for &mb in &MB_SWEEP {
        for &w in &WORKER_SWEEP {
            bench_ppo_fused(mb, w, &mut cells);
            bench_fnn_bce(mb, w, &mut cells);
            bench_gru_bptt(mb, w, &mut cells);
        }
    }

    let mut table = Table::new(
        "native NN training throughput (rows/sec; fused PPO + FNN BCE + GRU BPTT)",
        &["op", "minibatch", "nn_workers", "rows/s", "ms/update", "speedup vs w=1"],
    );
    for c in &cells {
        table.row(&[
            c.op.into(),
            c.minibatch.to_string(),
            c.nn_workers.to_string(),
            format!("{:.0}", c.rows_per_sec),
            format!("{:.2}", c.ms_per_update),
            format!("{:.2}x", c.speedup_vs_serial),
        ]);
    }
    table.print();

    // Hand-rolled JSON (no serde in the offline crate set).
    let mut json = String::from("[\n");
    for (i, c) in cells.iter().enumerate() {
        json.push_str(&format!(
            "  {{\"op\": \"{}\", \"minibatch\": {}, \"nn_workers\": {}, \
             \"rows_per_sec\": {:.1}, \"ms_per_update\": {:.3}, \
             \"speedup_vs_serial\": {:.3}, \"backend\": \"native\"}}{}\n",
            c.op,
            c.minibatch,
            c.nn_workers,
            c.rows_per_sec,
            c.ms_per_update,
            c.speedup_vs_serial,
            if i + 1 == cells.len() { "" } else { "," }
        ));
    }
    json.push_str("]\n");
    println!("{json}");
    if let Err(e) = std::fs::create_dir_all("results")
        .and_then(|_| std::fs::File::create("results/bench_ppo_update.json"))
        .and_then(|mut f| f.write_all(json.as_bytes()))
    {
        eprintln!("could not write results/bench_ppo_update.json: {e}");
    }

    // Headline for the acceptance criterion.
    if let Some(c) = cells
        .iter()
        .find(|c| c.op == "ppo_fused" && c.minibatch == 512 && c.nn_workers == 4)
    {
        println!(
            "headline: ppo_fused mb=512 nn_workers=4 -> {:.2}x vs serial ({:.0} rows/s)",
            c.speedup_vs_serial, c.rows_per_sec
        );
    }
}
