//! Appendix B / Figure 8: the spurious-correlation ablation. Trains the
//! traffic AIP on π₀ data with the d-set vs the full ALSH (lights included)
//! and reports held-out CE on-policy vs off-policy (actuated controller).

use ials::config::ExperimentConfig;
use ials::coordinator::run_figure;
use ials::runtime::Runtime;
use std::rc::Rc;

fn main() {
    ials::util::logger::init();
    let rt = Rc::new(Runtime::load_or_native("artifacts").expect("runtime"));
    let mut base = ExperimentConfig::default();
    base.aip.dataset_size = 30_000;
    base.aip.train_epochs = 6;
    base.results_dir = "results/bench".into();
    run_figure(&rt, "fig8", &base).expect("figure run failed");
}
