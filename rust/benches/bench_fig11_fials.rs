//! Appendix E (Figures 11 + 12): F-IALS conditions — fixed marginal
//! influence predictors vs the trained AIP and the GS, for both domains,
//! at a bench-sized budget. Full scale: `repro figure --name fig11/fig12`.

use ials::config::ExperimentConfig;
use ials::coordinator::run_figure;
use ials::runtime::Runtime;
use std::rc::Rc;

fn main() {
    ials::util::logger::init();
    let rt = Rc::new(Runtime::load_or_native("artifacts").expect("runtime"));
    let mut base = ExperimentConfig::default();
    base.seeds = vec![1];
    base.ppo.total_steps = 16_384;
    base.eval_every = 8_192;
    base.eval_episodes = 2;
    base.aip.dataset_size = 20_000;
    base.aip.train_epochs = 4;
    base.results_dir = "results/bench".into();
    run_figure(&rt, "fig11", &base).expect("fig11 failed");

    // Fig 12 shares the F-IALS machinery with a data-estimated marginal.
    let mut wh = base.clone();
    wh.aip.train_epochs = 12;
    wh.aip.lr = 3e-3;
    wh.aip.dataset_size = 24_000;
    run_figure(&rt, "fig12", &wh).expect("fig12 failed");
}
