//! End-to-end driver (the paper's headline claim, Fig 3): train the same
//! traffic agent on the GS and on the IALS, wall-clock both, and verify
//! final GS performance parity. This is the repo's full-stack validation:
//! Rust sims + Algorithm 1 collection + compiled AIP training + IALS +
//! compiled PPO + GS evaluation, all composing in one run.
//!
//! Run: `cargo run --release --example traffic_speedup`
//! (budget ~ a few minutes; results also land in EXPERIMENTS.md format)

use ials::bench_harness::Table;
use ials::config::{ExperimentConfig, SimulatorKind};
use ials::coordinator::experiment::evaluate_actuated;
use ials::coordinator::run_condition;
use ials::metrics::write_curve;
use ials::runtime::Runtime;
use std::rc::Rc;

fn main() -> ials::Result<()> {
    ials::util::logger::init();
    let rt = Rc::new(Runtime::load_or_native("artifacts")?);

    let mut base = ExperimentConfig::default();
    base.name = "speedup".into();
    base.ppo.total_steps = 49_152; // 24 PPO iterations
    base.eval_every = 8_192;
    base.eval_episodes = 3;
    base.aip.dataset_size = 30_000;
    base.aip.train_epochs = 4;

    let mut table = Table::new(
        "traffic: GS vs IALS end-to-end training (seed 1)",
        &["condition", "prep s", "train s", "total s", "aip CE", "final eval"],
    );

    let mut results = Vec::new();
    for sim in [SimulatorKind::Gs, SimulatorKind::Ials, SimulatorKind::UntrainedIals] {
        let mut cfg = base.clone();
        cfg.simulator = sim;
        let r = run_condition(&rt, &cfg, 1)?;
        write_curve(format!("results/speedup/{}_seed1.csv", r.condition), &r.curve)?;
        table.row(&[
            r.condition.clone(),
            format!("{:.2}", r.prep_secs),
            format!("{:.2}", r.train_secs),
            format!("{:.2}", r.total_secs()),
            format!("{:.4}", r.aip_ce),
            format!("{:.4}", r.final_eval),
        ]);
        results.push(r);
    }
    let actuated = evaluate_actuated(&base, 3, 777);
    table.row(&[
        "actuated-baseline".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("{actuated:.4}"),
    ]);
    table.print();

    let gs = &results[0];
    let ials = &results[1];
    println!(
        "IALS total {:.2}s vs GS total {:.2}s -> {:.2}x wall-clock; final {:.4} vs {:.4}",
        ials.total_secs(),
        gs.total_secs(),
        gs.total_secs() / ials.total_secs(),
        ials.final_eval,
        gs.final_eval
    );
    Ok(())
}
