//! Warehouse scenario (paper §5.3): train the purple robot on the IALS —
//! the GRU influence predictor (Pallas fused-GRU kernel inside the
//! compiled step artifact) stands in for the 35 scripted robots.
//!
//! Run: `cargo run --release --example warehouse_training`

use ials::config::{DomainKind, ExperimentConfig, SimulatorKind};
use ials::coordinator::run_condition;
use ials::metrics::write_curve;
use ials::runtime::Runtime;
use std::rc::Rc;

fn main() -> ials::Result<()> {
    ials::util::logger::init();
    let rt = Rc::new(Runtime::load_or_native("artifacts")?);
    let mut cfg = ExperimentConfig::default();
    cfg.name = "warehouse-demo".into();
    cfg.domain = DomainKind::Warehouse;
    cfg.simulator = SimulatorKind::Ials;
    cfg.warehouse.frame_stack = 8; // the paper's memory agent (App F)
    cfg.ppo.total_steps = 32_768;
    cfg.eval_every = 8_192;
    cfg.eval_episodes = 3;
    cfg.aip.dataset_size = 24_000;
    cfg.aip.train_epochs = 12;
    cfg.aip.lr = 3e-3;

    let r = run_condition(&rt, &cfg, 1)?;
    write_curve("results/warehouse-demo/curve_seed1.csv", &r.curve)?;
    println!("\nlearning curve (wall-clock s -> items/step on the GS):");
    for p in &r.curve {
        println!("  {:7.2}s  steps {:>6}  eval {:.4}", p.wall_clock_s, p.env_steps, p.eval_mean);
    }
    println!(
        "\nAIP prep {:.2}s (held-out CE {:.4}), PPO {:.2}s, final eval {:.4}",
        r.prep_secs, r.aip_ce, r.train_secs, r.final_eval
    );
    Ok(())
}
