//! §5.4 in miniature: with items vanishing after exactly 8 steps, compare
//! the memory (GRU) and memoryless (FNN) influence predictors — held-out
//! CE and the item-lifetime histograms of Fig 6 (bottom).
//!
//! Run: `cargo run --release --example memory_experiment`

use ials::bench_harness::Table;
use ials::config::{DomainKind, ExperimentConfig, SimulatorKind};
use ials::coordinator::experiment::{item_lifetime_histogram, prepare_predictor};
use ials::runtime::Runtime;
use std::rc::Rc;

fn main() -> ials::Result<()> {
    ials::util::logger::init();
    let rt = Rc::new(Runtime::load_or_native("artifacts")?);
    let mut base = ExperimentConfig::default();
    base.domain = DomainKind::Warehouse;
    base.simulator = SimulatorKind::Ials;
    base.warehouse.fixed_item_lifetime = 8;
    base.aip.dataset_size = 24_000;
    base.aip.train_epochs = 25;
    base.aip.lr = 3e-3;

    let mut table = Table::new(
        "memory experiment: AIP held-out CE (items expire at exactly 8 steps)",
        &["AIP", "held-out CE", "prep s"],
    );
    for (label, seq) in [("M (GRU)", 8usize), ("NM (FNN)", 1usize)] {
        let mut cfg = base.clone();
        cfg.aip.seq_len = seq;
        let prep = prepare_predictor(&rt, &cfg, 1, 16)?;
        table.row(&[
            label.into(),
            format!("{:.4}", prep.aip_ce),
            format!("{:.1}", prep.prep_secs),
        ]);
    }
    table.print();

    // Fig 6 bottom: how long items survive under each IALS.
    for (label, seq) in [("M-IALS", 8usize), ("NM-IALS", 1usize)] {
        let mut cfg = base.clone();
        cfg.aip.seq_len = seq;
        let ages = item_lifetime_histogram(&rt, &cfg, 1, 4000)?;
        let mut hist = [0usize; 17];
        for &a in &ages {
            hist[(a as usize).min(16)] += 1;
        }
        println!("\n{label}: lifetime histogram ({} removals)", ages.len());
        for (age, &n) in hist.iter().enumerate() {
            if n > 0 {
                let bar = "#".repeat((n * 60 / ages.len().max(1)).max(1));
                println!("  age {age:>2}: {n:>5} {bar}");
            }
        }
    }
    println!("\nExpected: M-IALS concentrates at age 8 (the paper's deterministic");
    println!("lifetime); NM-IALS spreads widely (it can only match the marginal).");
    Ok(())
}
