//! Quickstart: the library's public API in ~60 lines.
//!
//! 1. Load the AOT runtime (`make artifacts` first).
//! 2. Collect an influence dataset from the traffic GS (Algorithm 1).
//! 3. Train the approximate influence predictor offline.
//! 4. Build the IALS (Algorithm 2) and train a PPO agent on it.
//! 5. Evaluate the agent back on the GS.
//!
//! Run: `cargo run --release --example quickstart`

use ials::collect::{collect_dataset, FeatureKind};
use ials::config::ExperimentConfig;
use ials::coordinator::evaluate;
use ials::core::VecEnv;
use ials::ials::IalsVecEnv;
use ials::influence::{evaluate_ce, train_fnn, NeuralAip};
use ials::rl::{Policy, PpoTrainer};
use ials::runtime::Runtime;
use ials::sim::traffic::{TrafficGlobalEnv, TrafficLocalEnv};
use std::rc::Rc;

fn main() -> ials::Result<()> {
    let rt = Rc::new(Runtime::load_or_native("artifacts")?);
    let cfg = ExperimentConfig::default();

    // --- Algorithm 1: dataset from the global simulator -----------------
    let mut gs = TrafficGlobalEnv::new(&cfg.traffic);
    let data = collect_dataset(&mut gs, 20_000, 1, FeatureKind::Dset);
    println!(
        "collected {} (d_t, u_t) pairs; marginals {:?}",
        data.total_steps(),
        data.u_marginals()
    );

    // --- Train the influence predictor (Eq. 3) --------------------------
    let mut aip = NeuralAip::new(rt.clone(), "aip_traffic", 16)?;
    let losses = train_fnn(&rt, &mut aip.store, "aip_traffic_update", &data, 4, 256, 1e-3, 1)?;
    println!("AIP cross-entropy per epoch: {losses:?}");
    let mut heldout_gs = TrafficGlobalEnv::new(&cfg.traffic);
    let heldout = collect_dataset(&mut heldout_gs, 4_000, 99, FeatureKind::Dset);
    println!("held-out CE: {:.4}", evaluate_ce(&mut aip, &heldout)?);

    // --- Algorithm 2: the influence-augmented local simulator -----------
    let locals: Vec<TrafficLocalEnv> =
        (0..16).map(|_| TrafficLocalEnv::new(&cfg.traffic)).collect();
    let mut ials_env = IalsVecEnv::new(locals, Box::new(aip));
    ials_env.reset_all(1);

    // --- PPO on the IALS -------------------------------------------------
    let mut policy = Policy::new(rt.clone(), "policy_traffic", 16)?;
    policy.reinit(1)?;
    let mut trainer = PpoTrainer::new(&cfg.ppo, ials_env.obs_dim(), 1);
    for iter in 0..8 {
        let stats = trainer.train_iteration(&mut ials_env, &mut policy)?;
        println!(
            "iter {iter}: rollout reward {:.4}, entropy {:.3}",
            stats.rollout_reward, stats.entropy
        );
    }

    // --- Evaluate on the real (global) system ---------------------------
    let mut eval_env = ials::coordinator::experiment::make_eval_env(&cfg);
    let result = evaluate(eval_env.as_mut(), &mut policy, 3, 7)?;
    println!(
        "GS evaluation after IALS training: mean speed {:.4} (over {} episodes)",
        result.mean, result.episodes
    );
    Ok(())
}
