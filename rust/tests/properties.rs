//! Property-based tests (testkit) over the substrates: invariants that
//! must hold for arbitrary seeds/parameters, not just the unit-test cases.

use ials::config::TrafficConfig;
use ials::core::{Environment, GlobalEnv, LocalEnv};
use ials::dbn::Dag;
use ials::rl::compute_gae;
use ials::sim::traffic::network::{grid_network, source_links};
use ials::sim::traffic::{TrafficGlobalEnv, TrafficLocalEnv};
use ials::sim::warehouse::{WarehouseGlobalEnv, WarehouseLocalEnv};
use ials::testkit::forall;
use ials::util::Pcg32;

#[test]
fn prop_traffic_network_conserves_cars() {
    forall("traffic network conserves cars", 25, |g| {
        let grid = g.usize_in(2, 4);
        let lane = g.usize_in(4, 8);
        let mut net = grid_network(grid, lane, g.f32_in(0.3, 1.0));
        let sources = source_links(&net);
        let mut rng = Pcg32::seeded(g.rng().next_u64());
        let mut spawned = 0usize;
        let mut exited = 0usize;
        let steps = g.usize_in(50, 200);
        for t in 0..steps {
            let phases: Vec<bool> = (0..net.nodes.len()).map(|n| (t + n) % 6 < 3).collect();
            exited += net.tick(&phases, &mut rng);
            for &s in &sources {
                if rng.bernoulli(0.2) && net.spawn(s, &mut rng) {
                    spawned += 1;
                }
            }
        }
        assert_eq!(spawned, exited + net.total_cars());
    });
}

#[test]
fn prop_traffic_obs_is_binary_plus_phase() {
    forall("traffic obs in {0,1}", 10, |g| {
        let mut cfg = TrafficConfig::default();
        cfg.inflow_prob = g.f32_in(0.0, 0.5);
        let mut env = TrafficGlobalEnv::new(&cfg);
        env.reset(g.rng().next_u64());
        let mut obs = vec![0.0f32; env.obs_dim()];
        for _ in 0..50 {
            env.step(g.usize_in(0, 1));
            env.observe(&mut obs);
            assert!(obs.iter().all(|&x| x == 0.0 || x == 1.0));
            // phase one-hot
            assert_eq!(obs[40] + obs[41], 1.0);
        }
    });
}

#[test]
fn prop_local_sim_ignores_seed_for_geometry() {
    forall("LS geometry is seed-independent", 10, |g| {
        let cfg = TrafficConfig::default();
        let mut a = TrafficLocalEnv::new(&cfg);
        let mut b = TrafficLocalEnv::new(&cfg);
        a.reset(g.rng().next_u64());
        b.reset(g.rng().next_u64());
        assert_eq!(a.obs_dim(), b.obs_dim());
        assert_eq!(a.dset_dim(), b.dset_dim());
        // With identical influence streams and actions the *occupancy*
        // dynamics agree (turn decisions differ, but cell counts match
        // under always-straight configs only — so just check bounds).
        let mut d = vec![0.0f32; a.dset_dim()];
        for t in 0..100 {
            let u = [g.bool(), g.bool(), g.bool(), g.bool()];
            a.step_with_influence(t % 2, &u);
            a.dset(&mut d);
            let total: f32 = d.iter().sum();
            assert!(total <= 40.0);
        }
    });
}

#[test]
fn prop_gae_zero_rewards_zero_values_gives_zero() {
    forall("GAE of the zero process is zero", 30, |g| {
        let b = g.usize_in(1, 4);
        let t = g.usize_in(1, 16);
        let rewards = vec![0.0f32; t * b];
        let dones = vec![false; t * b];
        let values = vec![0.0f32; t * b];
        let boot = vec![0.0f32; b];
        let mut adv = vec![0.0f32; t * b];
        let mut ret = vec![0.0f32; t * b];
        compute_gae(
            &rewards,
            &dones,
            &values,
            &boot,
            g.f32_in(0.0, 1.0),
            g.f32_in(0.0, 1.0),
            &mut adv,
            &mut ret,
        );
        assert!(adv.iter().all(|&x| x == 0.0));
        assert!(ret.iter().all(|&x| x == 0.0));
    });
}

#[test]
fn prop_gae_returns_equal_adv_plus_values() {
    forall("returns = advantages + values", 30, |g| {
        let b = g.usize_in(1, 3);
        let t = g.usize_in(1, 12);
        let n = t * b;
        let rewards = g.vec_f32(n, n, -1.0, 1.0);
        let values = g.vec_f32(n, n, -1.0, 1.0);
        let dones: Vec<bool> = (0..n).map(|_| g.bool()).collect();
        let boot = g.vec_f32(b, b, -1.0, 1.0);
        let mut adv = vec![0.0f32; n];
        let mut ret = vec![0.0f32; n];
        compute_gae(&rewards, &dones, &values, &boot, 0.97, 0.9, &mut adv, &mut ret);
        for i in 0..n {
            assert!((ret[i] - (adv[i] + values[i])).abs() < 1e-5);
        }
    });
}

#[test]
fn prop_dseparation_is_symmetric() {
    forall("d-separation is symmetric in X and Y", 40, |g| {
        // Random small DAG over 8 nodes (edges only i->j for i<j: acyclic).
        let mut dag = Dag::new();
        let names: Vec<String> = (0..8).map(|i| format!("n{i}")).collect();
        for n in &names {
            dag.node(n);
        }
        for i in 0..8 {
            for j in (i + 1)..8 {
                if g.bool() && g.bool() {
                    dag.edge(&names[i], &names[j]);
                }
            }
        }
        assert!(dag.is_acyclic());
        let x = g.usize_in(0, 7);
        let mut y = g.usize_in(0, 7);
        if y == x {
            y = (y + 1) % 8;
        }
        let z: Vec<usize> = (0..8).filter(|&k| k != x && k != y && g.bool()).collect();
        let a = dag.d_separated(&[x], &[y], &z);
        let b = dag.d_separated(&[y], &[x], &z);
        assert_eq!(a, b);
    });
}

#[test]
fn prop_dseparation_full_conditioning_of_parents_blocks_roots() {
    forall("conditioning on all parents blocks non-descendant roots", 25, |g| {
        // Chain with a side root: r (root), r -> m, m -> t, plus a root s
        // unconnected. s ⟂ t | anything.
        let mut dag = Dag::new();
        dag.edge("r", "m");
        dag.edge("m", "t");
        dag.node("s");
        let z: Vec<&str> = if g.bool() { vec!["m"] } else { vec![] };
        assert!(dag.d_separated_names(&["s"], &["t"], &z).unwrap());
    });
}

#[test]
fn prop_warehouse_obs_onehot_position() {
    forall("warehouse obs position is one-hot", 10, |g| {
        let cfg = ials::config::WarehouseConfig::default();
        let mut env = WarehouseGlobalEnv::new(&cfg);
        env.reset(g.rng().next_u64());
        let mut obs = vec![0.0f32; env.obs_dim()];
        for _ in 0..60 {
            env.step(g.usize_in(0, 4));
            env.observe(&mut obs);
            assert_eq!(obs[..25].iter().sum::<f32>(), 1.0);
            assert!(obs.iter().all(|&x| x == 0.0 || x == 1.0));
        }
    });
}

#[test]
fn prop_warehouse_ls_reward_only_on_items() {
    forall("LS reward requires an active item", 10, |g| {
        let mut cfg = ials::config::WarehouseConfig::default();
        cfg.item_prob = 0.0; // no items can ever appear
        let mut env = WarehouseLocalEnv::new(&cfg);
        env.reset(g.rng().next_u64());
        for _ in 0..80 {
            let u: Vec<bool> = (0..12).map(|_| g.bool()).collect();
            let s = env.step_with_influence(g.usize_in(0, 4), &u);
            assert_eq!(s.reward, 0.0, "no items -> no reward, ever");
        }
    });
}

#[test]
fn prop_influence_dataset_split_partitions() {
    forall("dataset split partitions episodes", 20, |g| {
        let mut data = ials::influence::InfluenceDataset::new(3, 2);
        let eps = g.usize_in(1, 10);
        for e in 0..eps {
            data.begin_episode();
            for t in 0..g.usize_in(1, 30) {
                data.push(&[e as f32, t as f32, 0.0], &[g.bool() as u8 as f32, 0.0]);
            }
        }
        let frac = g.f32_in(0.0, 1.0) as f64;
        let mut rng = Pcg32::seeded(g.rng().next_u64());
        let (tr, he) = data.split(frac, &mut rng);
        assert_eq!(tr.episodes.len() + he.episodes.len(), eps);
        assert_eq!(tr.total_steps() + he.total_steps(), data.total_steps());
    });
}

#[test]
fn prop_config_roundtrip_core_fields() {
    forall("config parses its own field grammar", 30, |g| {
        let steps = g.usize_in(1, 100) * 2048;
        let lr = g.f32_in(1e-5, 1e-2);
        let toml = format!(
            "[experiment]\nname = \"p{}\"\ndomain = \"warehouse\"\n[ppo]\ntotal_steps = {}\nlr = {}\n",
            g.case, steps, lr
        );
        let cfg = ials::config::ExperimentConfig::from_toml(&toml).unwrap();
        assert_eq!(cfg.ppo.total_steps, steps);
        assert!((cfg.ppo.lr - lr).abs() < 1e-9);
    });
}
