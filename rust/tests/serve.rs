//! End-to-end tests of the serving runtime (`ials::serve`) against real
//! TCP connections — the acceptance criteria of the serving PRs:
//!
//! 1. act responses are *bitwise* identical whether requests arrive
//!    serially, coalesced into one batched forward, pipelined down one
//!    keep-alive connection, or routed through a multi-run server;
//! 2. a full queue sheds with `503 + Retry-After` while every accepted
//!    request still completes;
//! 3. a corrupt hot-reload candidate is rejected with a structured 409
//!    and subsequent responses are bitwise identical to the old params —
//!    per run, with sibling runs untouched;
//! 4. no malformed or hostile input panics or wedges the server, on the
//!    first request of a connection or any later one;
//! 5. SIGINT drains in-flight requests and exits 0 (subprocess test).
//!
//! Every test fabricates checkpoints directly through the public
//! `CheckpointManager` — no training required.

use ials::runtime::checkpoint::{checkpoint_file_name, CheckpointManager};
use ials::runtime::native::{EngineScratch, PolicyView};
use ials::serve::snapshot::{inspect_dir, snapshot_from_payload};
use ials::serve::{json, Server, ServeOptions};
use ials::testkit::fault::{
    flip_bit, read_one_response, send_garbage, send_oversized_body, send_truncated_request,
    slow_loris_request, SERVE_STALL_ENV,
};
use ials::util::state::StateWriter;
use ials::util::Pcg32;
use std::io::{BufRead, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Barrier};
use std::time::Duration;

// ---------------------------------------------------------------------------
// Checkpoint fabrication (the exact `write_checkpoint` payload layout)
// ---------------------------------------------------------------------------

const OBS: usize = 6;
const HID: usize = 8;
const ACT: usize = 3;

/// The eight policy tensors `PolicyView::resolve` needs, seeded.
fn policy_tensors(obs: usize, hid: usize, act: usize, seed: u64) -> Vec<(String, Vec<f32>)> {
    let mut rng = Pcg32::seeded(seed);
    let mut tensor = |name: &str, n: usize| {
        let vals: Vec<f32> =
            (0..n).map(|_| (rng.next_u32() as f32 / u32::MAX as f32) - 0.5).collect();
        (name.to_string(), vals)
    };
    vec![
        tensor("w1", obs * hid),
        tensor("b1", hid),
        tensor("w2", hid * hid),
        tensor("b2", hid),
        tensor("w_pi", hid * act),
        tensor("b_pi", act),
        tensor("w_v", hid),
        tensor("b_v", 1),
    ]
}

/// A checkpoint payload in the exact layout `MultiLearnerRun::write_checkpoint`
/// produces: meta geometry, then per learner seed / tensors / opaque
/// loop-state and env-state blobs.
fn checkpoint_payload(k: usize, hid: usize, salt: u64) -> Vec<u8> {
    let mut w = StateWriter::new();
    w.str("ials"); // domain
    w.str("ials"); // simulator
    w.str("policy"); // policy model
    w.usize(k);
    w.usize(8); // num_envs
    w.usize(16); // rollout_len
    w.usize(1024); // total_steps
    w.usize(256); // eval_every
    w.usize(3); // rounds_done
    for l in 0..k {
        w.u64(100 + l as u64);
        let tensors = policy_tensors(OBS, hid, ACT, salt * 1000 + l as u64);
        w.usize(tensors.len());
        for (name, vals) in &tensors {
            w.str(name);
            w.f32s(vals);
        }
        w.bytes(&[1, 2, 3]); // opaque loop state (serving skips it)
        w.bytes(&[4, 5]); // opaque env state (serving skips it)
    }
    w.into_bytes()
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ials_serve_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn save_checkpoint(dir: &Path, iter: usize, payload: &[u8]) {
    CheckpointManager::new(dir, 16).save(iter, payload).unwrap();
}

fn test_opts() -> ServeOptions {
    ServeOptions {
        port: 0,
        batch_window: Duration::from_millis(2),
        max_batch: 64,
        queue_capacity: 256,
        workers: 4,
        read_timeout: Duration::from_millis(2_000),
        write_timeout: Duration::from_millis(2_000),
        request_timeout: Duration::from_millis(5_000),
        max_body_bytes: 1 << 20,
        max_requests_per_conn: 1_000,
        idle_timeout: Duration::from_millis(2_000),
        engine_stall: None,
        inject_panic: false,
    }
}

/// `Server::spawn` over a single run directory (most tests host one).
fn spawn_one(dir: &Path, opts: ServeOptions) -> Server {
    Server::spawn(&[dir.to_path_buf()], opts).unwrap()
}

// ---------------------------------------------------------------------------
// A minimal blocking HTTP client
// ---------------------------------------------------------------------------

fn exchange(addr: SocketAddr, raw: &[u8]) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(15))).unwrap();
    s.write_all(raw).unwrap();
    let mut out = Vec::new();
    let _ = s.read_to_end(&mut out);
    String::from_utf8_lossy(&out).to_string()
}

/// One-connection-per-request GET: sends `Connection: close` so
/// `read_to_end` terminates against the keep-alive server.
fn get(addr: SocketAddr, path: &str) -> String {
    exchange(addr, format!("GET {path} HTTP/1.1\r\nConnection: close\r\n\r\n").as_bytes())
}

/// One-connection-per-request POST (`Connection: close`, like [`get`]).
fn post(addr: SocketAddr, path: &str, body: &str) -> String {
    let raw = format!(
        "POST {path} HTTP/1.1\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    exchange(addr, raw.as_bytes())
}

/// A raw keep-alive POST request (no `Connection` header — HTTP/1.1
/// persists by default); pair with [`read_one_response`].
fn keepalive_post(path: &str, body: &str) -> String {
    format!("POST {path} HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}", body.len())
}

/// Connect with a bounded read timeout (keep-alive tests frame their own
/// responses instead of reading to EOF).
fn connect(addr: SocketAddr) -> TcpStream {
    let s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(15))).unwrap();
    s
}

fn status_of(resp: &str) -> u16 {
    resp.split(' ').nth(1).and_then(|s| s.parse().ok()).unwrap_or_else(|| {
        panic!("no status line in response: {resp:?}");
    })
}

fn body_of(resp: &str) -> &str {
    resp.split_once("\r\n\r\n").map(|(_, b)| b).unwrap_or("")
}

fn obs_body(obs: &[f32]) -> String {
    format!("{{\"obs\": {}}}", json::nums(obs))
}

/// Distinct observation vectors per request index.
fn obs_for(i: usize) -> Vec<f32> {
    (0..OBS).map(|d| (i as f32 * 0.31 + d as f32 * 0.17) - 0.9).collect()
}

/// The exact response body the server must produce for (payload, learner,
/// obs), computed independently through the same public kernels.
fn expected_act_body(payload: &[u8], learner: usize, obs: &[f32]) -> String {
    let snap = snapshot_from_payload(0, payload).unwrap();
    let view = PolicyView::resolve(&snap.stores[learner]).unwrap();
    let mut scratch = EngineScratch::new(view.hid, view.hid);
    let mut logits = vec![0.0f32; view.act_dim];
    let mut values = vec![0.0f32; 1];
    view.forward_rows(1, obs, &mut logits, &mut values, &mut scratch);
    let mut action = 0;
    for (i, &x) in logits.iter().enumerate() {
        if x > logits[action] {
            action = i;
        }
    }
    format!(
        "{{\"learner\":{learner},\"action\":{action},\"value\":{},\"logits\":{}}}",
        json::num(values[0]),
        json::nums(&logits)
    )
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[test]
fn act_roundtrip_health_meta_and_request_validation() {
    let dir = fresh_dir("roundtrip");
    let payload = checkpoint_payload(2, HID, 7);
    save_checkpoint(&dir, 10, &payload);
    let server = spawn_one(&dir, test_opts());
    let addr = server.addr();

    let health = get(addr, "/healthz");
    assert_eq!(status_of(&health), 200, "{health}");
    assert_eq!(body_of(&health), "{\"status\":\"ok\"}");

    let ready = get(addr, "/readyz");
    assert_eq!(status_of(&ready), 200, "{ready}");
    assert!(body_of(&ready).contains("\"checkpoint_iteration\":10"), "{ready}");

    let meta = get(addr, "/v1/meta");
    assert_eq!(status_of(&meta), 200, "{meta}");
    for want in [
        "\"checkpoint_iteration\":10",
        "\"learners\":2",
        &format!("\"obs_dim\":{OBS}"),
        &format!("\"act_dim\":{ACT}"),
        &format!("\"hidden\":{HID}"),
        "\"policy_model\":\"policy\"",
    ] {
        assert!(body_of(&meta).contains(want), "meta missing {want}: {meta}");
    }

    // The act response is exactly the independently computed forward.
    for learner in [0usize, 1] {
        let obs = obs_for(learner);
        let resp = post(addr, &format!("/v1/learners/{learner}/act"), &obs_body(&obs));
        assert_eq!(status_of(&resp), 200, "{resp}");
        assert_eq!(body_of(&resp), expected_act_body(&payload, learner, &obs), "{resp}");
    }

    // Request validation: every rejection is structured, the server stays up.
    let cases = [
        ("GET", "/v1/learners/0/act", String::new(), 405),
        ("POST", "/v1/learners/kittens/act", obs_body(&obs_for(0)), 404),
        ("POST", "/v1/learners/9/act", obs_body(&obs_for(0)), 404),
        ("POST", "/v1/learners/0/act", "{\"obs\": [1, 2]}".to_string(), 400),
        ("POST", "/v1/learners/0/act", "{\"obs\": oops}".to_string(), 400),
        ("POST", "/nope", String::new(), 404),
    ];
    for (method, path, body, want) in cases {
        let raw = format!(
            "{method} {path} HTTP/1.1\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let resp = exchange(addr, raw.as_bytes());
        assert_eq!(status_of(&resp), want, "{method} {path}: {resp}");
        assert!(body_of(&resp).contains("\"error\""), "{method} {path}: {resp}");
        assert!(body_of(&resp).contains("\"code\""), "{method} {path}: {resp}");
    }

    server.begin_shutdown();
    server.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn batched_responses_are_bitwise_identical_to_serial() {
    let dir = fresh_dir("batched");
    let payload = checkpoint_payload(2, HID, 21);
    save_checkpoint(&dir, 1, &payload);
    let mut opts = test_opts();
    opts.batch_window = Duration::from_millis(10);
    opts.workers = 8;
    let server = spawn_one(&dir, opts);
    let addr = server.addr();

    const N: usize = 8;
    // Serial pass: one request at a time — every batch has one row.
    let serial: Vec<String> = (0..N)
        .map(|i| {
            let resp = post(addr, &format!("/v1/learners/{}/act", i % 2), &obs_body(&obs_for(i)));
            assert_eq!(status_of(&resp), 200, "{resp}");
            body_of(&resp).to_string()
        })
        .collect();

    // Concurrent pass: N threads release together so the 10 ms window
    // coalesces them into multi-row batches (mixed across learners).
    let barrier = Arc::new(Barrier::new(N));
    let handles: Vec<_> = (0..N)
        .map(|i| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                let resp =
                    post(addr, &format!("/v1/learners/{}/act", i % 2), &obs_body(&obs_for(i)));
                assert_eq!(status_of(&resp), 200, "{resp}");
                body_of(&resp).to_string()
            })
        })
        .collect();
    let batched: Vec<String> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    for i in 0..N {
        assert_eq!(
            batched[i], serial[i],
            "request {i}: batched response must be bitwise identical to serial"
        );
        assert_eq!(serial[i], expected_act_body(&payload, i % 2, &obs_for(i)));
    }

    server.begin_shutdown();
    server.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn full_queue_sheds_503_while_accepted_requests_complete() {
    let dir = fresh_dir("shed");
    save_checkpoint(&dir, 1, &checkpoint_payload(1, HID, 3));
    let mut opts = test_opts();
    opts.queue_capacity = 2;
    opts.workers = 8;
    // Stall the engine so the bounded job queue fills deterministically
    // while the barrier-released clients all submit.
    opts.engine_stall = Some(Duration::from_millis(1_000));
    let server = spawn_one(&dir, opts);
    let addr = server.addr();

    const N: usize = 8;
    let barrier = Arc::new(Barrier::new(N));
    let handles: Vec<_> = (0..N)
        .map(|_| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                post(addr, "/v1/learners/0/act", &obs_body(&obs_for(0)))
            })
        })
        .collect();
    let responses: Vec<String> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    let ok = responses.iter().filter(|r| status_of(r) == 200).count();
    let shed = responses.iter().filter(|r| status_of(r) == 503).count();
    assert_eq!(ok + shed, N, "every response is a 200 or a shed 503: {responses:?}");
    assert!(ok >= 1, "the accepted (queued) requests must complete: {responses:?}");
    assert!(shed >= 1, "with capacity 2 and {N} concurrent requests some must shed");
    for resp in responses.iter().filter(|r| status_of(r) == 503) {
        assert!(resp.contains("retry-after: 1"), "a shed response carries Retry-After: {resp}");
        assert!(resp.contains("queue is full"), "a shed response names the cause: {resp}");
    }
    for resp in responses.iter().filter(|r| status_of(r) == 200) {
        assert!(body_of(resp).contains("\"action\""), "{resp}");
    }

    server.begin_shutdown();
    server.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn hot_reload_swaps_atomically_and_rejects_corruption() {
    let dir = fresh_dir("reload");
    let payload_v1 = checkpoint_payload(1, HID, 5);
    save_checkpoint(&dir, 1, &payload_v1);
    let server = spawn_one(&dir, test_opts());
    let addr = server.addr();
    let obs = obs_for(4);

    let before = post(addr, "/v1/learners/0/act", &obs_body(&obs));
    assert_eq!(status_of(&before), 200, "{before}");
    assert_eq!(body_of(&before), expected_act_body(&payload_v1, 0, &obs));

    // A newer, different checkpoint: reload swaps to it.
    let payload_v2 = checkpoint_payload(1, HID, 6);
    save_checkpoint(&dir, 2, &payload_v2);
    let reload = post(addr, "/admin/reload", "");
    assert_eq!(status_of(&reload), 200, "{reload}");
    assert!(body_of(&reload).contains("\"from_iteration\":1"), "{reload}");
    assert!(body_of(&reload).contains("\"to_iteration\":2"), "{reload}");
    let after = post(addr, "/v1/learners/0/act", &obs_body(&obs));
    assert_eq!(body_of(&after), expected_act_body(&payload_v2, 0, &obs));
    assert_ne!(body_of(&after), body_of(&before), "new params must serve after reload");

    // A corrupt newest checkpoint: reload is rejected with a structured
    // 409 and the old snapshot keeps serving, bit for bit.
    save_checkpoint(&dir, 3, &checkpoint_payload(1, HID, 9));
    flip_bit(dir.join(checkpoint_file_name(3)), 120, 2).unwrap();
    let rejected = post(addr, "/admin/reload", "");
    assert_eq!(status_of(&rejected), 409, "{rejected}");
    assert!(body_of(&rejected).contains("reload rejected"), "{rejected}");
    let still = post(addr, "/v1/learners/0/act", &obs_body(&obs));
    assert_eq!(
        body_of(&still),
        body_of(&after),
        "after a rejected reload the old params must serve bitwise-identically"
    );

    // A geometry-changing checkpoint is also rejected.
    save_checkpoint(&dir, 4, &checkpoint_payload(1, HID * 2, 11));
    let mismatched = post(addr, "/admin/reload", "");
    assert_eq!(status_of(&mismatched), 409, "{mismatched}");
    assert!(body_of(&mismatched).contains("geometry"), "{mismatched}");
    let still2 = post(addr, "/v1/learners/0/act", &obs_body(&obs));
    assert_eq!(body_of(&still2), body_of(&after));

    server.begin_shutdown();
    server.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn hostile_inputs_never_panic_or_wedge_the_server() {
    let dir = fresh_dir("hostile");
    save_checkpoint(&dir, 1, &checkpoint_payload(1, HID, 13));
    let mut opts = test_opts();
    opts.read_timeout = Duration::from_millis(300);
    opts.max_body_bytes = 4096;
    let server = spawn_one(&dir, opts);
    let addr = server.addr();

    let body = obs_body(&obs_for(0));
    let canonical = format!(
        "POST /v1/learners/0/act HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .into_bytes();

    // Truncation at *every* byte boundary of a canonical request: the
    // server must answer a structured 4xx/5xx or close cleanly — and
    // must still be alive afterwards.
    for cut in 0..canonical.len() {
        let reply = send_truncated_request(addr, &canonical, cut).unwrap();
        let text = String::from_utf8_lossy(&reply);
        if !text.is_empty() {
            let status = status_of(&text);
            assert!(
                (400..=599).contains(&status),
                "truncation at {cut} must be a structured error, got: {text}"
            );
        }
    }

    // Seeded garbage (not HTTP at all), several lengths and seeds.
    for (len, seed) in [(1usize, 1u64), (64, 2), (1024, 3)] {
        let reply = send_garbage(addr, len, seed).unwrap();
        let text = String::from_utf8_lossy(&reply);
        if !text.is_empty() {
            assert!((400..=599).contains(&status_of(&text)), "garbage ({len}, {seed}): {text}");
        }
    }

    // Declared-oversized body: rejected from the header alone.
    let reply = send_oversized_body(addr, "/v1/learners/0/act", 1 << 20).unwrap();
    let text = String::from_utf8_lossy(&reply);
    assert_eq!(status_of(&text), 413, "{text}");

    // Slow loris: an unfinished head is answered 408 by the read timeout,
    // not allowed to pin a worker forever.
    let prefix = b"POST /v1/learners/0/act HTTP/1.1\r\nContent-";
    let reply = slow_loris_request(addr, prefix, Duration::from_millis(900)).unwrap();
    let text = String::from_utf8_lossy(&reply);
    assert_eq!(status_of(&text), 408, "{text}");

    // Zero-length body on act: structured 400 from the JSON parser.
    let resp = post(addr, "/v1/learners/0/act", "");
    assert_eq!(status_of(&resp), 400, "{resp}");

    // After the whole matrix the server still serves correctly.
    let resp = post(addr, "/v1/learners/0/act", &body);
    assert_eq!(status_of(&resp), 200, "server must survive the matrix: {resp}");

    server.begin_shutdown();
    server.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn handler_panic_is_isolated_to_its_connection() {
    let dir = fresh_dir("panic");
    save_checkpoint(&dir, 1, &checkpoint_payload(1, HID, 17));
    let mut opts = test_opts();
    opts.inject_panic = true;
    let server = spawn_one(&dir, opts);
    let addr = server.addr();

    let raw = "POST /v1/learners/0/act HTTP/1.1\r\nx-inject-panic: 1\r\nContent-Length: 0\r\n\r\n";
    let resp = exchange(addr, raw.as_bytes());
    assert_eq!(status_of(&resp), 500, "{resp}");
    assert!(body_of(&resp).contains("panicked"), "{resp}");

    // The panic was confined to that connection.
    let resp = post(addr, "/v1/learners/0/act", &obs_body(&obs_for(0)));
    assert_eq!(status_of(&resp), 200, "the server must survive a handler panic: {resp}");

    server.begin_shutdown();
    server.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn inspect_reports_metadata_and_corruption() {
    let dir = fresh_dir("inspect");
    save_checkpoint(&dir, 1, &checkpoint_payload(2, HID, 19));
    save_checkpoint(&dir, 2, &checkpoint_payload(2, HID, 20));
    flip_bit(dir.join(checkpoint_file_name(2)), 80, 5).unwrap();

    let lines = inspect_dir(&dir).unwrap();
    assert_eq!(lines.len(), 2, "{lines:?}");
    assert!(lines[0].contains("OK"), "{}", lines[0]);
    for want in ["iter=1", "v1", "learners=2", &format!("obs={OBS}"), &format!("hid={HID}")] {
        assert!(lines[0].contains(want), "missing {want}: {}", lines[0]);
    }
    assert!(lines[1].contains("CORRUPT"), "{}", lines[1]);
    assert!(lines[1].contains("iter=2"), "{}", lines[1]);
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Keep-alive, pipelining and the multi-run router
// ---------------------------------------------------------------------------

#[test]
fn pipelined_keepalive_responses_arrive_in_order_bitwise_identical() {
    let dir = fresh_dir("pipeline");
    let payload = checkpoint_payload(2, HID, 29);
    save_checkpoint(&dir, 1, &payload);
    let server = spawn_one(&dir, test_opts());
    let addr = server.addr();

    // Reference pass: one connection per request (Connection: close).
    const N: usize = 6;
    let reference: Vec<String> = (0..N)
        .map(|i| {
            let path = format!("/v1/runs/0/learners/{}/act", i % 2);
            let resp = post(addr, &path, &obs_body(&obs_for(i)));
            assert_eq!(status_of(&resp), 200, "{resp}");
            body_of(&resp).to_string()
        })
        .collect();

    // Pipelined pass: all N requests written back-to-back down ONE
    // connection before anything is read; responses must come back in
    // request order, byte-identical to the per-connection pass.
    let stream = connect(addr);
    let mut wire = String::new();
    for i in 0..N {
        let path = format!("/v1/runs/0/learners/{}/act", i % 2);
        wire.push_str(&keepalive_post(&path, &obs_body(&obs_for(i))));
    }
    let mut w = &stream;
    w.write_all(wire.as_bytes()).unwrap();
    let mut reader = std::io::BufReader::new(&stream);
    for (i, want) in reference.iter().enumerate() {
        let (head, body) = read_one_response(&mut reader).unwrap();
        assert!(head.starts_with("HTTP/1.1 200"), "request {i}: {head}");
        assert!(head.contains("connection: keep-alive"), "request {i}: {head}");
        let body = String::from_utf8(body).unwrap();
        assert_eq!(&body, want, "request {i}: pipelined body must match close-per-request body");
        assert_eq!(body, expected_act_body(&payload, i % 2, &obs_for(i)), "request {i}");
    }
    drop(reader);
    drop(stream);

    server.begin_shutdown();
    server.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// The PR acceptance criterion: a two-run keep-alive server returns
/// byte-identical `/act` bodies to the single-run close-per-request
/// server for the same checkpoint and requests.
#[test]
fn two_run_keepalive_server_matches_single_run_close_server_bitwise() {
    let dir_a = fresh_dir("runa");
    let dir_b = fresh_dir("runb");
    let payload_a = checkpoint_payload(2, HID, 31);
    let payload_b = checkpoint_payload(1, HID, 37);
    save_checkpoint(&dir_a, 5, &payload_a);
    save_checkpoint(&dir_b, 9, &payload_b);

    // The old shape: one run, driven one-connection-per-request.
    let single = spawn_one(&dir_a, test_opts());
    // The new shape: both runs behind one router, driven over keep-alive.
    let multi = Server::spawn(&[dir_a.clone(), dir_b.clone()], test_opts()).unwrap();
    let names = multi.run_names();
    assert_eq!(names.len(), 2, "{names:?}");

    let stream = connect(multi.addr());
    let mut reader = std::io::BufReader::new(&stream);
    for i in 0..4 {
        let learner = i % 2;
        let obs = obs_for(i);
        let reference = post(single.addr(), &format!("/v1/learners/{learner}/act"), &obs_body(&obs));
        assert_eq!(status_of(&reference), 200, "{reference}");
        let path = format!("/v1/runs/{}/learners/{learner}/act", names[0]);
        let mut w = &stream;
        w.write_all(keepalive_post(&path, &obs_body(&obs)).as_bytes()).unwrap();
        let (head, body) = read_one_response(&mut reader).unwrap();
        assert!(head.starts_with("HTTP/1.1 200"), "request {i}: {head}");
        assert_eq!(
            String::from_utf8(body).unwrap(),
            body_of(&reference),
            "request {i}: multi-run keep-alive body must match single-run close body"
        );
    }
    // The sibling run serves its own checkpoint on the same connection.
    let obs = obs_for(9);
    let path = format!("/v1/runs/{}/learners/0/act", names[1]);
    let mut w = &stream;
    w.write_all(keepalive_post(&path, &obs_body(&obs)).as_bytes()).unwrap();
    let (head, body) = read_one_response(&mut reader).unwrap();
    assert!(head.starts_with("HTTP/1.1 200"), "{head}");
    assert_eq!(String::from_utf8(body).unwrap(), expected_act_body(&payload_b, 0, &obs));
    drop(reader);
    drop(stream);

    single.begin_shutdown();
    single.join().unwrap();
    multi.begin_shutdown();
    multi.join().unwrap();
    std::fs::remove_dir_all(&dir_a).ok();
    std::fs::remove_dir_all(&dir_b).ok();
}

#[test]
fn meta_v2_enumerates_runs_and_aliases_carry_deprecation_headers() {
    let dir_a = fresh_dir("metaa");
    let dir_b = fresh_dir("metab");
    let payload_a = checkpoint_payload(2, HID, 41);
    save_checkpoint(&dir_a, 3, &payload_a);
    save_checkpoint(&dir_b, 8, &checkpoint_payload(1, HID, 43));
    let server = Server::spawn(&[dir_a.clone(), dir_b.clone()], test_opts()).unwrap();
    let addr = server.addr();
    let names = server.run_names();

    let meta = get(addr, "/v1/meta");
    assert_eq!(status_of(&meta), 200, "{meta}");
    let body = body_of(&meta);
    assert!(body.contains("\"api_version\":2"), "{body}");
    assert!(body.contains("\"runs\":["), "{body}");
    for name in &names {
        assert!(body.contains(&format!("\"name\":\"{name}\"")), "missing run {name}: {body}");
    }
    assert!(body.contains("\"checkpoint_iteration\":3"), "run-0 mirror fields: {body}");
    assert!(body.contains("\"checkpoint_iteration\":8"), "run 1 entry: {body}");
    let ready = get(addr, "/readyz");
    assert!(body_of(&ready).contains("\"runs\":2"), "{ready}");

    // The deprecated single-run alias still answers — via run 0 — and is
    // flagged with Deprecation + Link successor-version headers.
    let obs = obs_for(2);
    let alias = post(addr, "/v1/learners/0/act", &obs_body(&obs));
    assert_eq!(status_of(&alias), 200, "{alias}");
    let lower = alias.to_lowercase();
    assert!(lower.contains("deprecation: true"), "{alias}");
    let link = format!("link: </v1/runs/{}/learners/0/act>; rel=\"successor-version\"", names[0])
        .to_lowercase();
    assert!(lower.contains(&link), "missing {link:?}: {alias}");
    assert_eq!(body_of(&alias), expected_act_body(&payload_a, 0, &obs), "alias serves run 0");

    // The successor route answers the same bytes without the headers.
    let new = post(addr, &format!("/v1/runs/{}/learners/0/act", names[0]), &obs_body(&obs));
    assert_eq!(status_of(&new), 200, "{new}");
    assert!(!new.to_lowercase().contains("deprecation:"), "{new}");
    assert_eq!(body_of(&new), body_of(&alias));

    server.begin_shutdown();
    server.join().unwrap();
    std::fs::remove_dir_all(&dir_a).ok();
    std::fs::remove_dir_all(&dir_b).ok();
}

#[test]
fn router_unknown_run_learner_and_malformed_paths_are_structured_404s() {
    let dir = fresh_dir("router404");
    save_checkpoint(&dir, 1, &checkpoint_payload(1, HID, 47));
    let server = spawn_one(&dir, test_opts());
    let addr = server.addr();
    let name = server.run_names()[0].clone();
    let body = obs_body(&obs_for(0));

    let cases: Vec<(String, u16, &str)> = vec![
        ("/v1/runs/nosuchrun/learners/0/act".to_string(), 404, "unknown_run"),
        (format!("/v1/runs/{name}/learners/7/act"), 404, "unknown_learner"),
        (format!("/v1/runs/{name}/learners/zebra/act"), 404, "unknown_learner"),
        (format!("/v1/runs/{name}"), 404, "not_found"),
        (format!("/v1/runs/{name}/nothing"), 404, "not_found"),
    ];
    for (path, want_status, want_code) in cases {
        let resp = post(addr, &path, &body);
        assert_eq!(status_of(&resp), want_status, "{path}: {resp}");
        assert!(
            body_of(&resp).contains(&format!("\"code\":\"{want_code}\"")),
            "{path}: want code {want_code}: {resp}"
        );
    }
    let resp = get(addr, &format!("/v1/runs/{name}/learners/0/act"));
    assert_eq!(status_of(&resp), 405, "{resp}");
    assert!(body_of(&resp).contains("\"code\":\"method_not_allowed\""), "{resp}");
    // An unknown-run error names the runs that ARE hosted.
    let resp = post(addr, "/v1/runs/nosuchrun/learners/0/act", &body);
    assert!(body_of(&resp).contains(&name), "unknown_run lists hosted runs: {resp}");

    server.begin_shutdown();
    server.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn keepalive_hostile_matrix_never_wedges() {
    let dir = fresh_dir("kahostile");
    save_checkpoint(&dir, 1, &checkpoint_payload(1, HID, 53));
    let mut opts = test_opts();
    opts.read_timeout = Duration::from_millis(400);
    opts.idle_timeout = Duration::from_millis(400);
    let server = spawn_one(&dir, opts);
    let addr = server.addr();
    let good = keepalive_post("/v1/runs/0/learners/0/act", &obs_body(&obs_for(0)));

    // (a) Truncation mid-second-request: the first request answers 200,
    // then half a request plus close gets a structured error response.
    {
        let stream = connect(addr);
        let mut w = &stream;
        w.write_all(good.as_bytes()).unwrap();
        let mut reader = std::io::BufReader::new(&stream);
        let (head, _) = read_one_response(&mut reader).unwrap();
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        let mut w = &stream;
        w.write_all(&good.as_bytes()[..25]).unwrap();
        stream.shutdown(std::net::Shutdown::Write).unwrap();
        let mut rest = Vec::new();
        let _ = reader.read_to_end(&mut rest);
        let text = String::from_utf8_lossy(&rest);
        assert!(!text.is_empty(), "a truncated second request gets a structured error");
        assert!((400..=599).contains(&status_of(&text)), "{text}");
        assert!(text.contains("connection: close"), "a parse error closes: {text}");
    }

    // (b) Garbage after a valid request on the same connection.
    {
        let stream = connect(addr);
        let mut w = &stream;
        w.write_all(good.as_bytes()).unwrap();
        let mut reader = std::io::BufReader::new(&stream);
        let (head, _) = read_one_response(&mut reader).unwrap();
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        let mut w = &stream;
        w.write_all(b"\x00\xffgarbage not http\r\n\r\n").unwrap();
        let mut rest = Vec::new();
        let _ = reader.read_to_end(&mut rest);
        let text = String::from_utf8_lossy(&rest);
        assert!(!text.is_empty(), "garbage after a valid request gets a structured error");
        assert!((400..=599).contains(&status_of(&text)), "{text}");
    }

    // (c) Idle timeout: a connection that goes quiet after a served
    // request is closed silently (EOF, no response bytes).
    {
        let stream = connect(addr);
        let mut w = &stream;
        w.write_all(good.as_bytes()).unwrap();
        let mut reader = std::io::BufReader::new(&stream);
        let (head, _) = read_one_response(&mut reader).unwrap();
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        let mut rest = Vec::new();
        let n = reader.read_to_end(&mut rest).unwrap_or(rest.len());
        assert_eq!(n, 0, "idle close must be silent: {:?}", String::from_utf8_lossy(&rest));
    }

    // (d) Request cap: with max_requests_per_conn = 2 the second response
    // announces `connection: close` and a third request goes unanswered.
    {
        let mut opts = test_opts();
        opts.max_requests_per_conn = 2;
        let capped = spawn_one(&dir, opts);
        let stream = connect(capped.addr());
        let mut w = &stream;
        w.write_all(format!("{good}{good}").as_bytes()).unwrap();
        let mut reader = std::io::BufReader::new(&stream);
        let (h1, _) = read_one_response(&mut reader).unwrap();
        assert!(h1.contains("connection: keep-alive"), "{h1}");
        let (h2, _) = read_one_response(&mut reader).unwrap();
        assert!(h2.contains("connection: close"), "the cap-hitting response closes: {h2}");
        let mut w = &stream;
        let _ = w.write_all(good.as_bytes());
        let mut rest = Vec::new();
        let _ = reader.read_to_end(&mut rest);
        assert!(rest.is_empty(), "the capped connection must close after 2 responses");
        capped.begin_shutdown();
        capped.join().unwrap();
    }

    // After the whole matrix the server still serves correctly.
    let resp = post(addr, "/v1/runs/0/learners/0/act", &obs_body(&obs_for(0)));
    assert_eq!(status_of(&resp), 200, "server must survive the matrix: {resp}");

    server.begin_shutdown();
    server.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn per_run_reload_is_isolated_to_its_run() {
    let dir_a = fresh_dir("reloada");
    let dir_b = fresh_dir("reloadb");
    let payload_a1 = checkpoint_payload(1, HID, 61);
    let payload_b = checkpoint_payload(1, HID, 67);
    save_checkpoint(&dir_a, 1, &payload_a1);
    save_checkpoint(&dir_b, 9, &payload_b);
    let server = Server::spawn(&[dir_a.clone(), dir_b.clone()], test_opts()).unwrap();
    let addr = server.addr();
    let names = server.run_names();
    let obs = obs_for(5);

    let a_path = format!("/v1/runs/{}/learners/0/act", names[0]);
    let b_path = format!("/v1/runs/{}/learners/0/act", names[1]);
    let a_before = post(addr, &a_path, &obs_body(&obs));
    let b_before = post(addr, &b_path, &obs_body(&obs));
    assert_eq!(status_of(&a_before), 200, "{a_before}");
    assert_eq!(status_of(&b_before), 200, "{b_before}");

    // Reload run A to a newer checkpoint; run B must be untouched.
    let payload_a2 = checkpoint_payload(1, HID, 62);
    save_checkpoint(&dir_a, 2, &payload_a2);
    let reload = post(addr, &format!("/v1/runs/{}/admin/reload", names[0]), "");
    assert_eq!(status_of(&reload), 200, "{reload}");
    assert!(body_of(&reload).contains(&format!("\"run\":\"{}\"", names[0])), "{reload}");
    assert!(body_of(&reload).contains("\"to_iteration\":2"), "{reload}");

    let a_after = post(addr, &a_path, &obs_body(&obs));
    assert_eq!(body_of(&a_after), expected_act_body(&payload_a2, 0, &obs));
    assert_ne!(body_of(&a_after), body_of(&a_before), "run A must serve the new params");
    let b_after = post(addr, &b_path, &obs_body(&obs));
    assert_eq!(
        body_of(&b_after),
        body_of(&b_before),
        "a reload of run A must leave run B bitwise untouched"
    );

    // Meta reflects the per-run iterations.
    let meta = get(addr, "/v1/meta");
    assert!(body_of(&meta).contains("\"checkpoint_iteration\":2"), "{meta}");
    assert!(body_of(&meta).contains("\"checkpoint_iteration\":9"), "{meta}");

    server.begin_shutdown();
    server.join().unwrap();
    std::fs::remove_dir_all(&dir_a).ok();
    std::fs::remove_dir_all(&dir_b).ok();
}

/// SIGINT drain, end to end against the real binary: an in-flight request
/// (held by an injected engine stall) completes with a 200 while the
/// process shuts down, and the exit status is 0.
#[cfg(unix)]
#[test]
fn sigint_drains_in_flight_requests_and_exits_zero() {
    let dir = fresh_dir("drain");
    save_checkpoint(&dir, 1, &checkpoint_payload(1, HID, 23));

    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["serve", "--checkpoint-dir", dir.to_str().unwrap(), "--port", "0"])
        .env(SERVE_STALL_ENV, "800")
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .unwrap();

    // The first stdout line names the bound (ephemeral) address.
    let stdout = child.stdout.take().unwrap();
    let mut lines = std::io::BufReader::new(stdout).lines();
    let first = lines.next().expect("the server must print its address").unwrap();
    let addr: SocketAddr = first
        .strip_prefix("serving on http://")
        .unwrap_or_else(|| panic!("unexpected startup line: {first}"))
        .parse()
        .unwrap();

    // Fire a request that will be in flight (engine stalled 800 ms)...
    let in_flight = obs_body(&obs_for(1));
    let client = std::thread::spawn(move || post(addr, "/v1/learners/0/act", &in_flight));
    std::thread::sleep(Duration::from_millis(250));

    // ...then SIGINT the server while that request is still queued.
    let kill = std::process::Command::new("kill")
        .args(["-s", "INT", &child.id().to_string()])
        .status()
        .unwrap();
    assert!(kill.success());

    let resp = client.join().unwrap();
    assert_eq!(status_of(&resp), 200, "the in-flight request must complete: {resp}");

    let status = child.wait().unwrap();
    assert!(status.success(), "a drained shutdown must exit 0, got {status:?}");
    std::fs::remove_dir_all(&dir).ok();
}
