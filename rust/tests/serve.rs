//! End-to-end tests of the serving runtime (`ials::serve`) against real
//! TCP connections — the acceptance criteria of the serving PR:
//!
//! 1. act responses are *bitwise* identical whether requests arrive
//!    serially or are coalesced into one batched forward;
//! 2. a full queue sheds with `503 + Retry-After` while every accepted
//!    request still completes;
//! 3. a corrupt hot-reload candidate is rejected with a structured 409
//!    and subsequent responses are bitwise identical to the old params;
//! 4. no malformed or hostile input panics or wedges the server;
//! 5. SIGINT drains in-flight requests and exits 0 (subprocess test).
//!
//! Every test fabricates checkpoints directly through the public
//! `CheckpointManager` — no training required.

use ials::runtime::checkpoint::{checkpoint_file_name, CheckpointManager};
use ials::runtime::native::{EngineScratch, PolicyView};
use ials::serve::snapshot::{inspect_dir, snapshot_from_payload};
use ials::serve::{json, Server, ServeOptions};
use ials::testkit::fault::{
    flip_bit, send_garbage, send_oversized_body, send_truncated_request, slow_loris_request,
    SERVE_STALL_ENV,
};
use ials::util::state::StateWriter;
use ials::util::Pcg32;
use std::io::{BufRead, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Barrier};
use std::time::Duration;

// ---------------------------------------------------------------------------
// Checkpoint fabrication (the exact `write_checkpoint` payload layout)
// ---------------------------------------------------------------------------

const OBS: usize = 6;
const HID: usize = 8;
const ACT: usize = 3;

/// The eight policy tensors `PolicyView::resolve` needs, seeded.
fn policy_tensors(obs: usize, hid: usize, act: usize, seed: u64) -> Vec<(String, Vec<f32>)> {
    let mut rng = Pcg32::seeded(seed);
    let mut tensor = |name: &str, n: usize| {
        let vals: Vec<f32> =
            (0..n).map(|_| (rng.next_u32() as f32 / u32::MAX as f32) - 0.5).collect();
        (name.to_string(), vals)
    };
    vec![
        tensor("w1", obs * hid),
        tensor("b1", hid),
        tensor("w2", hid * hid),
        tensor("b2", hid),
        tensor("w_pi", hid * act),
        tensor("b_pi", act),
        tensor("w_v", hid),
        tensor("b_v", 1),
    ]
}

/// A checkpoint payload in the exact layout `MultiLearnerRun::write_checkpoint`
/// produces: meta geometry, then per learner seed / tensors / opaque
/// loop-state and env-state blobs.
fn checkpoint_payload(k: usize, hid: usize, salt: u64) -> Vec<u8> {
    let mut w = StateWriter::new();
    w.str("ials"); // domain
    w.str("ials"); // simulator
    w.str("policy"); // policy model
    w.usize(k);
    w.usize(8); // num_envs
    w.usize(16); // rollout_len
    w.usize(1024); // total_steps
    w.usize(256); // eval_every
    w.usize(3); // rounds_done
    for l in 0..k {
        w.u64(100 + l as u64);
        let tensors = policy_tensors(OBS, hid, ACT, salt * 1000 + l as u64);
        w.usize(tensors.len());
        for (name, vals) in &tensors {
            w.str(name);
            w.f32s(vals);
        }
        w.bytes(&[1, 2, 3]); // opaque loop state (serving skips it)
        w.bytes(&[4, 5]); // opaque env state (serving skips it)
    }
    w.into_bytes()
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ials_serve_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn save_checkpoint(dir: &Path, iter: usize, payload: &[u8]) {
    CheckpointManager::new(dir, 16).save(iter, payload).unwrap();
}

fn test_opts() -> ServeOptions {
    ServeOptions {
        port: 0,
        batch_window: Duration::from_millis(2),
        max_batch: 64,
        queue_capacity: 256,
        workers: 4,
        read_timeout: Duration::from_millis(2_000),
        write_timeout: Duration::from_millis(2_000),
        request_timeout: Duration::from_millis(5_000),
        max_body_bytes: 1 << 20,
        engine_stall: None,
        inject_panic: false,
    }
}

// ---------------------------------------------------------------------------
// A minimal blocking HTTP client
// ---------------------------------------------------------------------------

fn exchange(addr: SocketAddr, raw: &[u8]) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(15))).unwrap();
    s.write_all(raw).unwrap();
    let mut out = Vec::new();
    let _ = s.read_to_end(&mut out);
    String::from_utf8_lossy(&out).to_string()
}

fn get(addr: SocketAddr, path: &str) -> String {
    exchange(addr, format!("GET {path} HTTP/1.1\r\n\r\n").as_bytes())
}

fn post(addr: SocketAddr, path: &str, body: &str) -> String {
    let raw = format!("POST {path} HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}", body.len());
    exchange(addr, raw.as_bytes())
}

fn status_of(resp: &str) -> u16 {
    resp.split(' ').nth(1).and_then(|s| s.parse().ok()).unwrap_or_else(|| {
        panic!("no status line in response: {resp:?}");
    })
}

fn body_of(resp: &str) -> &str {
    resp.split_once("\r\n\r\n").map(|(_, b)| b).unwrap_or("")
}

fn obs_body(obs: &[f32]) -> String {
    format!("{{\"obs\": {}}}", json::nums(obs))
}

/// Distinct observation vectors per request index.
fn obs_for(i: usize) -> Vec<f32> {
    (0..OBS).map(|d| (i as f32 * 0.31 + d as f32 * 0.17) - 0.9).collect()
}

/// The exact response body the server must produce for (payload, learner,
/// obs), computed independently through the same public kernels.
fn expected_act_body(payload: &[u8], learner: usize, obs: &[f32]) -> String {
    let snap = snapshot_from_payload(0, payload).unwrap();
    let view = PolicyView::resolve(&snap.stores[learner]).unwrap();
    let mut scratch = EngineScratch::new(view.hid, view.hid);
    let mut logits = vec![0.0f32; view.act_dim];
    let mut values = vec![0.0f32; 1];
    view.forward_rows(1, obs, &mut logits, &mut values, &mut scratch);
    let mut action = 0;
    for (i, &x) in logits.iter().enumerate() {
        if x > logits[action] {
            action = i;
        }
    }
    format!(
        "{{\"learner\":{learner},\"action\":{action},\"value\":{},\"logits\":{}}}",
        json::num(values[0]),
        json::nums(&logits)
    )
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[test]
fn act_roundtrip_health_meta_and_request_validation() {
    let dir = fresh_dir("roundtrip");
    let payload = checkpoint_payload(2, HID, 7);
    save_checkpoint(&dir, 10, &payload);
    let server = Server::spawn(&dir, test_opts()).unwrap();
    let addr = server.addr();

    let health = get(addr, "/healthz");
    assert_eq!(status_of(&health), 200, "{health}");
    assert_eq!(body_of(&health), "{\"status\":\"ok\"}");

    let ready = get(addr, "/readyz");
    assert_eq!(status_of(&ready), 200, "{ready}");
    assert!(body_of(&ready).contains("\"checkpoint_iteration\":10"), "{ready}");

    let meta = get(addr, "/v1/meta");
    assert_eq!(status_of(&meta), 200, "{meta}");
    for want in [
        "\"checkpoint_iteration\":10",
        "\"learners\":2",
        &format!("\"obs_dim\":{OBS}"),
        &format!("\"act_dim\":{ACT}"),
        &format!("\"hidden\":{HID}"),
        "\"policy_model\":\"policy\"",
    ] {
        assert!(body_of(&meta).contains(want), "meta missing {want}: {meta}");
    }

    // The act response is exactly the independently computed forward.
    for learner in [0usize, 1] {
        let obs = obs_for(learner);
        let resp = post(addr, &format!("/v1/learners/{learner}/act"), &obs_body(&obs));
        assert_eq!(status_of(&resp), 200, "{resp}");
        assert_eq!(body_of(&resp), expected_act_body(&payload, learner, &obs), "{resp}");
    }

    // Request validation: every rejection is structured, the server stays up.
    let cases = [
        ("GET", "/v1/learners/0/act", String::new(), 405),
        ("POST", "/v1/learners/kittens/act", obs_body(&obs_for(0)), 404),
        ("POST", "/v1/learners/9/act", obs_body(&obs_for(0)), 404),
        ("POST", "/v1/learners/0/act", "{\"obs\": [1, 2]}".to_string(), 400),
        ("POST", "/v1/learners/0/act", "{\"obs\": oops}".to_string(), 400),
        ("POST", "/nope", String::new(), 404),
    ];
    for (method, path, body, want) in cases {
        let raw =
            format!("{method} {path} HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}", body.len());
        let resp = exchange(addr, raw.as_bytes());
        assert_eq!(status_of(&resp), want, "{method} {path}: {resp}");
        assert!(body_of(&resp).contains("\"error\""), "{method} {path}: {resp}");
    }

    server.begin_shutdown();
    server.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn batched_responses_are_bitwise_identical_to_serial() {
    let dir = fresh_dir("batched");
    let payload = checkpoint_payload(2, HID, 21);
    save_checkpoint(&dir, 1, &payload);
    let mut opts = test_opts();
    opts.batch_window = Duration::from_millis(10);
    opts.workers = 8;
    let server = Server::spawn(&dir, opts).unwrap();
    let addr = server.addr();

    const N: usize = 8;
    // Serial pass: one request at a time — every batch has one row.
    let serial: Vec<String> = (0..N)
        .map(|i| {
            let resp = post(addr, &format!("/v1/learners/{}/act", i % 2), &obs_body(&obs_for(i)));
            assert_eq!(status_of(&resp), 200, "{resp}");
            body_of(&resp).to_string()
        })
        .collect();

    // Concurrent pass: N threads release together so the 10 ms window
    // coalesces them into multi-row batches (mixed across learners).
    let barrier = Arc::new(Barrier::new(N));
    let handles: Vec<_> = (0..N)
        .map(|i| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                let resp =
                    post(addr, &format!("/v1/learners/{}/act", i % 2), &obs_body(&obs_for(i)));
                assert_eq!(status_of(&resp), 200, "{resp}");
                body_of(&resp).to_string()
            })
        })
        .collect();
    let batched: Vec<String> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    for i in 0..N {
        assert_eq!(
            batched[i], serial[i],
            "request {i}: batched response must be bitwise identical to serial"
        );
        assert_eq!(serial[i], expected_act_body(&payload, i % 2, &obs_for(i)));
    }

    server.begin_shutdown();
    server.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn full_queue_sheds_503_while_accepted_requests_complete() {
    let dir = fresh_dir("shed");
    save_checkpoint(&dir, 1, &checkpoint_payload(1, HID, 3));
    let mut opts = test_opts();
    opts.queue_capacity = 2;
    opts.workers = 8;
    // Stall the engine so the bounded job queue fills deterministically
    // while the barrier-released clients all submit.
    opts.engine_stall = Some(Duration::from_millis(1_000));
    let server = Server::spawn(&dir, opts).unwrap();
    let addr = server.addr();

    const N: usize = 8;
    let barrier = Arc::new(Barrier::new(N));
    let handles: Vec<_> = (0..N)
        .map(|_| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                post(addr, "/v1/learners/0/act", &obs_body(&obs_for(0)))
            })
        })
        .collect();
    let responses: Vec<String> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    let ok = responses.iter().filter(|r| status_of(r) == 200).count();
    let shed = responses.iter().filter(|r| status_of(r) == 503).count();
    assert_eq!(ok + shed, N, "every response is a 200 or a shed 503: {responses:?}");
    assert!(ok >= 1, "the accepted (queued) requests must complete: {responses:?}");
    assert!(shed >= 1, "with capacity 2 and {N} concurrent requests some must shed");
    for resp in responses.iter().filter(|r| status_of(r) == 503) {
        assert!(resp.contains("retry-after: 1"), "a shed response carries Retry-After: {resp}");
        assert!(resp.contains("queue is full"), "a shed response names the cause: {resp}");
    }
    for resp in responses.iter().filter(|r| status_of(r) == 200) {
        assert!(body_of(resp).contains("\"action\""), "{resp}");
    }

    server.begin_shutdown();
    server.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn hot_reload_swaps_atomically_and_rejects_corruption() {
    let dir = fresh_dir("reload");
    let payload_v1 = checkpoint_payload(1, HID, 5);
    save_checkpoint(&dir, 1, &payload_v1);
    let server = Server::spawn(&dir, test_opts()).unwrap();
    let addr = server.addr();
    let obs = obs_for(4);

    let before = post(addr, "/v1/learners/0/act", &obs_body(&obs));
    assert_eq!(status_of(&before), 200, "{before}");
    assert_eq!(body_of(&before), expected_act_body(&payload_v1, 0, &obs));

    // A newer, different checkpoint: reload swaps to it.
    let payload_v2 = checkpoint_payload(1, HID, 6);
    save_checkpoint(&dir, 2, &payload_v2);
    let reload = post(addr, "/admin/reload", "");
    assert_eq!(status_of(&reload), 200, "{reload}");
    assert!(body_of(&reload).contains("\"from_iteration\":1"), "{reload}");
    assert!(body_of(&reload).contains("\"to_iteration\":2"), "{reload}");
    let after = post(addr, "/v1/learners/0/act", &obs_body(&obs));
    assert_eq!(body_of(&after), expected_act_body(&payload_v2, 0, &obs));
    assert_ne!(body_of(&after), body_of(&before), "new params must serve after reload");

    // A corrupt newest checkpoint: reload is rejected with a structured
    // 409 and the old snapshot keeps serving, bit for bit.
    save_checkpoint(&dir, 3, &checkpoint_payload(1, HID, 9));
    flip_bit(dir.join(checkpoint_file_name(3)), 120, 2).unwrap();
    let rejected = post(addr, "/admin/reload", "");
    assert_eq!(status_of(&rejected), 409, "{rejected}");
    assert!(body_of(&rejected).contains("reload rejected"), "{rejected}");
    let still = post(addr, "/v1/learners/0/act", &obs_body(&obs));
    assert_eq!(
        body_of(&still),
        body_of(&after),
        "after a rejected reload the old params must serve bitwise-identically"
    );

    // A geometry-changing checkpoint is also rejected.
    save_checkpoint(&dir, 4, &checkpoint_payload(1, HID * 2, 11));
    let mismatched = post(addr, "/admin/reload", "");
    assert_eq!(status_of(&mismatched), 409, "{mismatched}");
    assert!(body_of(&mismatched).contains("geometry"), "{mismatched}");
    let still2 = post(addr, "/v1/learners/0/act", &obs_body(&obs));
    assert_eq!(body_of(&still2), body_of(&after));

    server.begin_shutdown();
    server.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn hostile_inputs_never_panic_or_wedge_the_server() {
    let dir = fresh_dir("hostile");
    save_checkpoint(&dir, 1, &checkpoint_payload(1, HID, 13));
    let mut opts = test_opts();
    opts.read_timeout = Duration::from_millis(300);
    opts.max_body_bytes = 4096;
    let server = Server::spawn(&dir, opts).unwrap();
    let addr = server.addr();

    let body = obs_body(&obs_for(0));
    let canonical = format!(
        "POST /v1/learners/0/act HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .into_bytes();

    // Truncation at *every* byte boundary of a canonical request: the
    // server must answer a structured 4xx/5xx or close cleanly — and
    // must still be alive afterwards.
    for cut in 0..canonical.len() {
        let reply = send_truncated_request(addr, &canonical, cut).unwrap();
        let text = String::from_utf8_lossy(&reply);
        if !text.is_empty() {
            let status = status_of(&text);
            assert!(
                (400..=599).contains(&status),
                "truncation at {cut} must be a structured error, got: {text}"
            );
        }
    }

    // Seeded garbage (not HTTP at all), several lengths and seeds.
    for (len, seed) in [(1usize, 1u64), (64, 2), (1024, 3)] {
        let reply = send_garbage(addr, len, seed).unwrap();
        let text = String::from_utf8_lossy(&reply);
        if !text.is_empty() {
            assert!((400..=599).contains(&status_of(&text)), "garbage ({len}, {seed}): {text}");
        }
    }

    // Declared-oversized body: rejected from the header alone.
    let reply = send_oversized_body(addr, "/v1/learners/0/act", 1 << 20).unwrap();
    let text = String::from_utf8_lossy(&reply);
    assert_eq!(status_of(&text), 413, "{text}");

    // Slow loris: an unfinished head is answered 408 by the read timeout,
    // not allowed to pin a worker forever.
    let prefix = b"POST /v1/learners/0/act HTTP/1.1\r\nContent-";
    let reply = slow_loris_request(addr, prefix, Duration::from_millis(900)).unwrap();
    let text = String::from_utf8_lossy(&reply);
    assert_eq!(status_of(&text), 408, "{text}");

    // Zero-length body on act: structured 400 from the JSON parser.
    let resp = post(addr, "/v1/learners/0/act", "");
    assert_eq!(status_of(&resp), 400, "{resp}");

    // After the whole matrix the server still serves correctly.
    let resp = post(addr, "/v1/learners/0/act", &body);
    assert_eq!(status_of(&resp), 200, "server must survive the matrix: {resp}");

    server.begin_shutdown();
    server.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn handler_panic_is_isolated_to_its_connection() {
    let dir = fresh_dir("panic");
    save_checkpoint(&dir, 1, &checkpoint_payload(1, HID, 17));
    let mut opts = test_opts();
    opts.inject_panic = true;
    let server = Server::spawn(&dir, opts).unwrap();
    let addr = server.addr();

    let raw = "POST /v1/learners/0/act HTTP/1.1\r\nx-inject-panic: 1\r\nContent-Length: 0\r\n\r\n";
    let resp = exchange(addr, raw.as_bytes());
    assert_eq!(status_of(&resp), 500, "{resp}");
    assert!(body_of(&resp).contains("panicked"), "{resp}");

    // The panic was confined to that connection.
    let resp = post(addr, "/v1/learners/0/act", &obs_body(&obs_for(0)));
    assert_eq!(status_of(&resp), 200, "the server must survive a handler panic: {resp}");

    server.begin_shutdown();
    server.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn inspect_reports_metadata_and_corruption() {
    let dir = fresh_dir("inspect");
    save_checkpoint(&dir, 1, &checkpoint_payload(2, HID, 19));
    save_checkpoint(&dir, 2, &checkpoint_payload(2, HID, 20));
    flip_bit(dir.join(checkpoint_file_name(2)), 80, 5).unwrap();

    let lines = inspect_dir(&dir).unwrap();
    assert_eq!(lines.len(), 2, "{lines:?}");
    assert!(lines[0].contains("OK"), "{}", lines[0]);
    for want in ["iter=1", "v1", "learners=2", &format!("obs={OBS}"), &format!("hid={HID}")] {
        assert!(lines[0].contains(want), "missing {want}: {}", lines[0]);
    }
    assert!(lines[1].contains("CORRUPT"), "{}", lines[1]);
    assert!(lines[1].contains("iter=2"), "{}", lines[1]);
    std::fs::remove_dir_all(&dir).ok();
}

/// SIGINT drain, end to end against the real binary: an in-flight request
/// (held by an injected engine stall) completes with a 200 while the
/// process shuts down, and the exit status is 0.
#[cfg(unix)]
#[test]
fn sigint_drains_in_flight_requests_and_exits_zero() {
    let dir = fresh_dir("drain");
    save_checkpoint(&dir, 1, &checkpoint_payload(1, HID, 23));

    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["serve", "--checkpoint-dir", dir.to_str().unwrap(), "--port", "0"])
        .env(SERVE_STALL_ENV, "800")
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .unwrap();

    // The first stdout line names the bound (ephemeral) address.
    let stdout = child.stdout.take().unwrap();
    let mut lines = std::io::BufReader::new(stdout).lines();
    let first = lines.next().expect("the server must print its address").unwrap();
    let addr: SocketAddr = first
        .strip_prefix("serving on http://")
        .unwrap_or_else(|| panic!("unexpected startup line: {first}"))
        .parse()
        .unwrap();

    // Fire a request that will be in flight (engine stalled 800 ms)...
    let in_flight = obs_body(&obs_for(1));
    let client = std::thread::spawn(move || post(addr, "/v1/learners/0/act", &in_flight));
    std::thread::sleep(Duration::from_millis(250));

    // ...then SIGINT the server while that request is still queued.
    let kill = std::process::Command::new("kill")
        .args(["-s", "INT", &child.id().to_string()])
        .status()
        .unwrap();
    assert!(kill.success());

    let resp = client.join().unwrap();
    assert_eq!(status_of(&resp), 200, "the in-flight request must complete: {resp}");

    let status = child.wait().unwrap();
    assert!(status.success(), "a drained shutdown must exit 0, got {status:?}");
    std::fs::remove_dir_all(&dir).ok();
}
