//! Cross-module warehouse-domain integration (no artifacts needed).

use ials::collect::{collect_dataset, FeatureKind};
use ials::config::WarehouseConfig;
use ials::core::{Environment, GlobalEnv};
use ials::sim::warehouse::WarehouseGlobalEnv;
use ials::util::Pcg32;

/// The fleet keeps the floor from saturating: long-run item occupancy
/// under scripted robots stays well below 100%.
#[test]
fn scripted_fleet_controls_item_backlog() {
    let cfg = WarehouseConfig::default();
    let mut env = WarehouseGlobalEnv::new(&cfg);
    env.reset(1);
    let mut d = vec![0.0f32; env.dset_dim()];
    let mut occ = 0.0f64;
    let mut n = 0usize;
    for t in 0..2000 {
        if env.step(4).done {
            env.reset(2 + t as u64);
        }
        env.dset(&mut d);
        occ += d[..12].iter().sum::<f32>() as f64 / 12.0;
        n += 1;
    }
    let rate = occ / n as f64;
    assert!(rate < 0.5, "occupancy should stay controlled, got {rate:.3}");
    assert!(rate > 0.005, "items should exist, got {rate:.3}");
}

/// A trained-region agent collects more by walking to items than by
/// standing still (environment is actually solvable).
#[test]
fn greedy_agent_outperforms_idle() {
    let cfg = WarehouseConfig::default();
    let run = |greedy: bool| {
        let mut env = WarehouseGlobalEnv::new(&cfg);
        let mut rng = Pcg32::seeded(9);
        let mut total = 0.0f64;
        for ep in 0..5 {
            env.reset(100 + ep);
            let mut obs = vec![0.0f32; env.obs_dim()];
            loop {
                let a = if greedy {
                    env.observe(&mut obs);
                    // naive greedy: walk toward any active item bit
                    pick_greedy(&obs, &mut rng)
                } else {
                    4 // stay
                };
                let s = env.step(a);
                total += s.reward as f64;
                if s.done {
                    break;
                }
            }
        }
        total
    };
    let greedy = run(true);
    let idle = run(false);
    assert!(greedy > idle, "moving toward items ({greedy}) must beat idling ({idle})");
}

/// Cheap hand policy: move toward the first active item's cell.
fn pick_greedy(obs: &[f32], rng: &mut Pcg32) -> usize {
    // obs = 25 position bits + 12 item bits; item cells in canonical order:
    // top (0,1..3), right (1..3,4), bottom (4,1..3), left (1..3,0).
    const ITEM_CELLS: [(usize, usize); 12] = [
        (0, 1),
        (0, 2),
        (0, 3),
        (1, 4),
        (2, 4),
        (3, 4),
        (4, 1),
        (4, 2),
        (4, 3),
        (1, 0),
        (2, 0),
        (3, 0),
    ];
    let pos = obs[..25].iter().position(|&x| x > 0.5).unwrap();
    let (r, c) = (pos / 5, pos % 5);
    for (k, &(ir, ic)) in ITEM_CELLS.iter().enumerate() {
        if obs[25 + k] > 0.5 {
            return if r < ir {
                1 // down
            } else if r > ir {
                0 // up
            } else if c < ic {
                3 // right
            } else if c > ic {
                2 // left
            } else {
                4
            };
        }
    }
    rng.below(5)
}

/// Memory-mode datasets: expiry events are perfectly predictable from an
/// 8-step item history — verify the raw signal exists (u fires exactly
/// when an item reaches age 8).
#[test]
fn memory_mode_dataset_has_deterministic_structure() {
    let mut cfg = WarehouseConfig::default();
    cfg.fixed_item_lifetime = 8;
    let mut env = WarehouseGlobalEnv::new(&cfg);
    let data = collect_dataset(&mut env, 3000, 5, FeatureKind::Dset);
    // For every episode: u[k]=1 at t implies the item bit k was set for
    // the previous 8 consecutive steps (it survived to exactly age 8).
    let mut fired = 0;
    for ep in &data.episodes {
        for t in 8..ep.steps {
            let u = ep.u_row(&data, t);
            for k in 0..12 {
                if u[k] > 0.5 {
                    fired += 1;
                    for back in 1..=7 {
                        let d = ep.d_row(&data, t - back);
                        assert!(
                            d[k] > 0.5,
                            "expired item must have been visible for 8 steps (t={t}, k={k}, back={back})"
                        );
                    }
                }
            }
        }
    }
    assert!(fired > 20, "expiries should occur: {fired}");
}

/// ALSH features strictly extend the d-set (position bitmap appended).
#[test]
fn alsh_extends_dset() {
    let cfg = WarehouseConfig::default();
    let mut env = WarehouseGlobalEnv::new(&cfg);
    env.reset(3);
    env.step(1);
    let mut d = vec![0.0f32; env.dset_dim()];
    let mut a = vec![0.0f32; env.alsh_dim()];
    env.dset(&mut d);
    env.alsh(&mut a);
    assert_eq!(&a[..24], &d[..]);
    assert_eq!(a[24..].iter().sum::<f32>(), 1.0, "position bitmap is one-hot");
}
