//! Parity and determinism tests for the native CPU backend. These never
//! skip: they build `Runtime::native*` directly, so the NN execution path
//! is exercised on every `cargo test` regardless of artifacts.
//!
//! Kernel-vs-scalar-reference parity (GEMM, GRU cell, log-softmax) lives
//! in `src/nn/kernels.rs`; this suite checks the *wired* runtime: artifact
//! classification, parameter binding order, fused-vs-minibatch update
//! equivalence, and bitwise run-to-run determinism of native PPO.

use ials::config::PpoConfig;
use ials::core::{Environment, GsVecEnv, Step, VecEnv};
use ials::rl::{Policy, PpoTrainer};
use ials::runtime::{DataArg, Runtime, SynthGeometry};
use ials::util::Pcg32;
use std::rc::Rc;

const TOL: f32 = 1e-5;

/// Scalar-reference policy forward for one observation row.
fn policy_fwd_ref(store: &ials::nn::ParamStore, obs: &[f32]) -> (Vec<f32>, f32) {
    let lin = |x: &[f32], w: &[f32], b: &[f32], n: usize| -> Vec<f32> {
        (0..n)
            .map(|j| {
                let mut acc = b[j];
                for (kk, &xv) in x.iter().enumerate() {
                    acc += xv * w[kk * n + j];
                }
                acc
            })
            .collect()
    };
    let tanh = |v: Vec<f32>| -> Vec<f32> { v.into_iter().map(|x| x.tanh()).collect() };
    let h1 = tanh(lin(obs, store.get("w1").unwrap(), store.get("b1").unwrap(), 64));
    let h2 = tanh(lin(&h1, store.get("w2").unwrap(), store.get("b2").unwrap(), 64));
    let logits = lin(&h2, store.get("w_pi").unwrap(), store.get("b_pi").unwrap(), 2);
    let value = lin(&h2, store.get("w_v").unwrap(), store.get("b_v").unwrap(), 1);
    (logits, value[0])
}

#[test]
fn native_policy_forward_matches_scalar_reference() {
    let rt = Runtime::native_default();
    let mut store = rt.load_store("policy_traffic").unwrap();
    let mut rng = Pcg32::seeded(42);
    let obs: Vec<f32> = (0..42).map(|_| rng.f32() * 2.0 - 1.0).collect();
    let outs = rt.call("policy_traffic_fwd_b1", &mut store, &[DataArg::F32(&obs)]).unwrap();
    let (want_logits, want_value) = policy_fwd_ref(&store, &obs);
    for (g, w) in outs[0].iter().zip(&want_logits) {
        assert!((g - w).abs() <= TOL, "logit {g} vs {w}");
    }
    assert!((outs[1][0] - want_value).abs() <= TOL);
}

#[test]
fn native_batched_forward_agrees_rowwise_with_b1() {
    let rt = Runtime::native_default();
    let mut store = rt.load_store("policy_traffic").unwrap();
    let mut rng = Pcg32::seeded(7);
    let obs: Vec<f32> = (0..16 * 42).map(|_| rng.f32() - 0.5).collect();
    let big = rt.call("policy_traffic_fwd_b16", &mut store, &[DataArg::F32(&obs)]).unwrap();
    for row in 0..16 {
        let small = rt
            .call(
                "policy_traffic_fwd_b1",
                &mut store,
                &[DataArg::F32(&obs[row * 42..(row + 1) * 42])],
            )
            .unwrap();
        for k in 0..2 {
            assert!((big[0][row * 2 + k] - small[0][k]).abs() <= TOL);
        }
        assert!((big[1][row] - small[1][0]).abs() <= TOL);
    }
}

#[test]
fn native_gru_step_matches_kernel_reference() {
    let rt = Runtime::native_default();
    let mut store = rt.load_store("aip_warehouse").unwrap();
    let mut rng = Pcg32::seeded(11);
    let h: Vec<f32> = (0..64).map(|_| rng.f32() - 0.5).collect();
    let d: Vec<f32> = (0..24).map(|_| rng.f32()).collect();
    let outs = rt
        .call(
            "aip_warehouse_step_b1",
            &mut store,
            &[DataArg::F32(&h), DataArg::F32(&d)],
        )
        .unwrap();
    // Scalar GRU reference (z|r|n fused gate layout): gx = x@w_x + b,
    // gh = h@w_h, and the candidate gate mixes r into the recurrent half.
    let w_x = store.get("w_x").unwrap();
    let w_h = store.get("w_h").unwrap();
    let b_g = store.get("b_g").unwrap();
    let gx = |col: usize| -> f32 {
        let mut acc = b_g[col];
        for (kk, &xv) in d.iter().enumerate() {
            acc += xv * w_x[kk * 192 + col];
        }
        acc
    };
    let gh = |col: usize| -> f32 {
        let mut acc = 0.0f32;
        for (kk, &hv) in h.iter().enumerate() {
            acc += hv * w_h[kk * 192 + col];
        }
        acc
    };
    let sig = |x: f32| 1.0 / (1.0 + (-x).exp());
    for j in 0..64 {
        let z = sig(gx(j) + gh(j));
        let r = sig(gx(64 + j) + gh(64 + j));
        let n = (gx(128 + j) + r * gh(128 + j)).tanh();
        let want = (1.0 - z) * n + z * h[j];
        assert!((outs[1][j] - want).abs() <= TOL, "h'[{j}]: {} vs {want}", outs[1][j]);
    }
    assert!(outs[0].iter().all(|&p| (0.0..=1.0).contains(&p)), "probs in [0,1]");
}

#[test]
fn native_fused_update_equals_minibatch_loop() {
    // Same data, same permutation: one fused call must produce bitwise the
    // same parameters as the explicit epochs x minibatches loop.
    let geom = SynthGeometry {
        rollout_b: 4,
        rollout_t: 16,
        ppo_epochs: 2,
        ppo_minibatch: 16,
        ..SynthGeometry::default()
    };
    let n = 64usize;
    let cfg = PpoConfig {
        num_envs: 4,
        rollout_len: 16,
        epochs: 2,
        minibatch: 16,
        ..PpoConfig::default()
    };
    let mut rng = Pcg32::seeded(3);
    let obs: Vec<f32> = (0..n * 42).map(|_| rng.f32() - 0.5).collect();
    let actions: Vec<i32> = (0..n).map(|_| rng.below(2) as i32).collect();
    let adv: Vec<f32> = (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect();
    let ret: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
    let logp: Vec<f32> = vec![(0.5f32).ln(); n];
    let mut perm: Vec<i32> = Vec::new();
    let mut order: Vec<usize> = (0..n).collect();
    for _ in 0..cfg.epochs {
        rng.shuffle(&mut order);
        perm.extend(order.iter().map(|&k| k as i32));
    }

    let rt1 = Rc::new(Runtime::native(&geom));
    let mut fused = Policy::new(rt1, "policy_traffic", 4).unwrap();
    fused.reinit(5).unwrap();
    let rt2 = Rc::new(Runtime::native(&geom));
    let mut looped = Policy::new(rt2, "policy_traffic", 4).unwrap();
    looped.reinit(5).unwrap();
    assert_eq!(fused.store.get("w1").unwrap(), looped.store.get("w1").unwrap());

    fused.update_fused(&cfg, &perm, &obs, &actions, &adv, &ret, &logp).unwrap();

    let mb = cfg.minibatch;
    let mut mb_obs = vec![0.0f32; mb * 42];
    let mut mb_act = vec![0i32; mb];
    let mut mb_adv = vec![0.0f32; mb];
    let mut mb_ret = vec![0.0f32; mb];
    let mut mb_lp = vec![0.0f32; mb];
    for chunk in perm.chunks_exact(mb) {
        for (row, &src) in chunk.iter().enumerate() {
            let s = src as usize;
            mb_obs[row * 42..(row + 1) * 42].copy_from_slice(&obs[s * 42..(s + 1) * 42]);
            mb_act[row] = actions[s];
            mb_adv[row] = adv[s];
            mb_ret[row] = ret[s];
            mb_lp[row] = logp[s];
        }
        looped.update_minibatch(&cfg, &mb_obs, &mb_act, &mb_adv, &mb_ret, &mb_lp).unwrap();
    }

    for name in ["w1", "b1", "w2", "b2", "w_pi", "b_pi", "w_v", "b_v", "adam_t"] {
        assert_eq!(
            fused.store.get(name).unwrap(),
            looped.store.get(name).unwrap(),
            "tensor {name} must match bitwise"
        );
    }
}

/// Deterministic 2-armed bandit in the traffic observation geometry.
struct Bandit {
    rng: Pcg32,
    t: usize,
}

impl Environment for Bandit {
    fn obs_dim(&self) -> usize {
        42
    }
    fn num_actions(&self) -> usize {
        2
    }
    fn reset(&mut self, seed: u64) {
        self.rng = Pcg32::seeded(seed);
        self.t = 0;
    }
    fn observe(&self, out: &mut [f32]) {
        out.fill(0.0);
        out[0] = 1.0;
    }
    fn step(&mut self, action: usize) -> Step {
        self.t += 1;
        let p = if action == 1 { 0.8 } else { 0.2 };
        let reward = if self.rng.bernoulli(p) { 1.0 } else { 0.0 };
        Step { reward, done: self.t >= 32 }
    }
}

fn run_native_ppo(seed: u64, iters: usize) -> (Vec<f32>, f64) {
    let rt = Rc::new(Runtime::native_default());
    let mut policy = Policy::new(rt, "policy_traffic", 16).unwrap();
    policy.reinit(seed).unwrap();
    let cfg = PpoConfig { lr: 1e-3, ..PpoConfig::default() };
    let mut trainer = PpoTrainer::new(&cfg, 42, seed);
    let mut env =
        GsVecEnv::new((0..16).map(|_| Bandit { rng: Pcg32::seeded(0), t: 0 }).collect());
    env.reset_all(seed);
    let mut curve = Vec::with_capacity(iters);
    for _ in 0..iters {
        let stats = trainer.train_iteration(&mut env, &mut policy).unwrap();
        curve.push(stats.rollout_reward);
    }
    (curve, policy.store.param_norm())
}

#[test]
fn native_ppo_runs_are_bitwise_deterministic() {
    let (curve_a, norm_a) = run_native_ppo(123, 3);
    let (curve_b, norm_b) = run_native_ppo(123, 3);
    assert_eq!(curve_a, curve_b, "same seed must give identical reward curves");
    assert_eq!(norm_a.to_bits(), norm_b.to_bits(), "parameters must match bitwise");
    let (curve_c, _) = run_native_ppo(124, 3);
    assert_ne!(curve_a, curve_c, "different seeds must differ");
}

#[test]
fn native_backend_reports_kind_and_geometry() {
    let rt = Runtime::native_default();
    assert_eq!(rt.backend_kind(), "native");
    assert_eq!(rt.geom("traffic_obs").unwrap(), 42);
    assert_eq!(rt.geom("gru_seq_t").unwrap(), 32);
    assert_eq!(rt.call_count(), 0);
    let mut store = rt.load_store("aip_traffic").unwrap();
    let d = vec![0.5f32; 16 * 40];
    rt.call("aip_traffic_fwd_b16", &mut store, &[DataArg::F32(&d)]).unwrap();
    assert_eq!(rt.call_count(), 1);
}
