//! Integration over the influence layer with real artifacts + real GS
//! data: the paper's CE orderings must hold, and the IALS must be usable
//! as a drop-in training simulator.

use ials::config::{ExperimentConfig, SimulatorKind};
use ials::coordinator::experiment::prepare_predictor;
use ials::core::VecEnv;
use ials::runtime::Runtime;
use std::rc::Rc;

fn runtime() -> Option<Rc<Runtime>> {
    // Compiled artifacts when present, the native CPU backend otherwise —
    // the paper's CE orderings must hold on either engine.
    Some(Rc::new(Runtime::load_or_native("artifacts").expect("runtime")))
}

fn base(sim: SimulatorKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.simulator = sim;
    cfg.aip.dataset_size = 6000;
    cfg.aip.train_epochs = 3;
    cfg
}

/// Fig 3 bottom panel ordering: trained AIP CE < untrained AIP CE.
#[test]
fn trained_aip_beats_untrained_on_traffic() {
    let Some(rt) = runtime() else { return };
    let trained = prepare_predictor(&rt, &base(SimulatorKind::Ials), 11, 16).unwrap();
    let untrained = prepare_predictor(&rt, &base(SimulatorKind::UntrainedIals), 11, 16).unwrap();
    assert!(
        trained.aip_ce < untrained.aip_ce - 0.05,
        "trained CE {} must beat untrained CE {}",
        trained.aip_ce,
        untrained.aip_ce
    );
    assert!(trained.prep_secs > 0.0);
    assert_eq!(untrained.prep_secs, 0.0);
}

/// Appendix E ordering (Eq. 9): trained < F-IALS(0.1) < F-IALS(0.5) —
/// the true boundary inflow is 0.1, so the 0.5 marginal is badly wrong.
#[test]
fn fials_ce_ordering_matches_eq9() {
    let Some(rt) = runtime() else { return };
    let trained = prepare_predictor(&rt, &base(SimulatorKind::Ials), 13, 16).unwrap();
    let mut f01 = base(SimulatorKind::FixedIals);
    f01.aip.fixed_p = 0.1;
    let mut f05 = base(SimulatorKind::FixedIals);
    f05.aip.fixed_p = 0.5;
    let ce01 = prepare_predictor(&rt, &f01, 13, 16).unwrap().aip_ce;
    let ce05 = prepare_predictor(&rt, &f05, 13, 16).unwrap().aip_ce;
    assert!(
        trained.aip_ce < ce01 && ce01 < ce05,
        "Eq. 9 ordering violated: trained {} / f0.1 {} / f0.5 {}",
        trained.aip_ce,
        ce01,
        ce05
    );
}

/// Warehouse: the data-estimated marginal (F-IALS) must beat a grossly
/// wrong constant but lose to the trained GRU (Eq. 10).
#[test]
fn warehouse_gru_beats_estimated_marginal() {
    let Some(rt) = runtime() else { return };
    let mut ials_cfg = base(SimulatorKind::Ials);
    ials_cfg.domain = ials::config::DomainKind::Warehouse;
    ials_cfg.aip.dataset_size = 16_000;
    ials_cfg.aip.train_epochs = 20; // BPTT sees dataset/(B*T) batches/epoch
    let mut fdata = base(SimulatorKind::FixedIals);
    fdata.domain = ials::config::DomainKind::Warehouse;
    fdata.aip.fixed_p = -1.0;
    let trained = prepare_predictor(&rt, &ials_cfg, 17, 16).unwrap();
    let marginal = prepare_predictor(&rt, &fdata, 17, 16).unwrap();
    assert!(
        trained.aip_ce < marginal.aip_ce,
        "Eq. 10: GRU CE {} must beat marginal CE {}",
        trained.aip_ce,
        marginal.aip_ce
    );
    assert!(marginal.prep_secs > 0.0, "10K-sample estimation is timed");
}

/// The IALS vec-env built from a *real* trained predictor steps correctly
/// and exposes the same interface geometry as the GS.
#[test]
fn ials_env_from_trained_predictor_steps() {
    let Some(rt) = runtime() else { return };
    let cfg = base(SimulatorKind::Ials);
    let prep = prepare_predictor(&rt, &cfg, 19, 16).unwrap();
    let mut env = ials::coordinator::experiment::make_train_env(&cfg, prep.predictor);
    let mut gs = ials::coordinator::experiment::make_train_env(&cfg, None);
    assert_eq!(env.obs_dim(), gs.obs_dim());
    assert_eq!(env.num_actions(), gs.num_actions());
    env.reset_all(5);
    let mut rewards = vec![0.0f32; 16];
    let mut dones = vec![false; 16];
    let actions = vec![0usize; 16];
    for _ in 0..20 {
        env.step_all(&actions, &mut rewards, &mut dones);
        assert!(rewards.iter().all(|r| r.is_finite()));
    }
}

/// Memory experiment prerequisite (Fig 6 bottom): under the fixed-lifetime
/// variant, the *recurrent* AIP learns the 8-step expiry far better than
/// the memoryless one.
#[test]
fn memory_aip_predicts_fixed_lifetime_better() {
    let Some(rt) = runtime() else { return };
    let mut m_cfg = base(SimulatorKind::Ials);
    m_cfg.domain = ials::config::DomainKind::Warehouse;
    m_cfg.warehouse.fixed_item_lifetime = 8;
    m_cfg.aip.seq_len = 8; // GRU
    m_cfg.aip.dataset_size = 24_000;
    m_cfg.aip.train_epochs = 50;
    m_cfg.aip.lr = 3e-3;
    let mut nm_cfg = m_cfg.clone();
    nm_cfg.aip.seq_len = 1; // FNN

    let m = prepare_predictor(&rt, &m_cfg, 23, 16).unwrap();
    let nm = prepare_predictor(&rt, &nm_cfg, 23, 16).unwrap();
    assert!(
        m.aip_ce < nm.aip_ce - 0.01,
        "M-AIP CE {} should beat NM-AIP CE {} on the deterministic-lifetime task",
        m.aip_ce,
        nm.aip_ce
    );
}
