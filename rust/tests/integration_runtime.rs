//! Integration tests over the full execution runtime. With compiled AOT
//! artifacts present (`make artifacts` + a real PJRT binding) this is the
//! python→HLO→PJRT→rust round trip; without them the same tests execute on
//! the native CPU backend against the synthesized manifest — either way,
//! every test runs.

use ials::nn::ParamStore;
use ials::runtime::{DataArg, Runtime};

fn runtime() -> Option<Runtime> {
    Some(Runtime::load_or_native("artifacts").expect("runtime"))
}

#[test]
fn policy_forward_shapes_and_finiteness() {
    let Some(rt) = runtime() else { return };
    let mut store = rt.load_store("policy_traffic").unwrap();
    let obs = vec![0.5f32; 16 * 42];
    let outs = rt.call("policy_traffic_fwd_b16", &mut store, &[DataArg::F32(&obs)]).unwrap();
    assert_eq!(outs.len(), 2);
    assert_eq!(outs[0].len(), 16 * 2); // logits
    assert_eq!(outs[1].len(), 16); // values
    assert!(outs.iter().flatten().all(|x| x.is_finite()));
}

#[test]
fn b1_and_b16_agree_rowwise() {
    let Some(rt) = runtime() else { return };
    let mut store = rt.load_store("policy_traffic").unwrap();
    let mut obs = vec![0.0f32; 16 * 42];
    for (i, x) in obs.iter_mut().enumerate() {
        *x = ((i % 7) as f32) * 0.1 - 0.3;
    }
    let big = rt.call("policy_traffic_fwd_b16", &mut store, &[DataArg::F32(&obs)]).unwrap();
    let row0 = &obs[..42];
    let small = rt.call("policy_traffic_fwd_b1", &mut store, &[DataArg::F32(row0)]).unwrap();
    for k in 0..2 {
        assert!(
            (big[0][k] - small[0][k]).abs() < 1e-5,
            "logit {k}: {} vs {}",
            big[0][k],
            small[0][k]
        );
    }
    assert!((big[1][0] - small[1][0]).abs() < 1e-5);
}

#[test]
fn aip_forward_probabilities() {
    let Some(rt) = runtime() else { return };
    let mut store = rt.load_store("aip_traffic").unwrap();
    let d = vec![1.0f32; 16 * 40];
    let outs = rt.call("aip_traffic_fwd_b16", &mut store, &[DataArg::F32(&d)]).unwrap();
    assert_eq!(outs[0].len(), 16 * 4);
    assert!(outs[0].iter().all(|&p| (0.0..=1.0).contains(&p)));
}

#[test]
fn gru_step_carries_state() {
    let Some(rt) = runtime() else { return };
    let mut store = rt.load_store("aip_warehouse").unwrap();
    let h0 = vec![0.0f32; 64];
    let d = vec![1.0f32; 24];
    let outs = rt
        .call(
            "aip_warehouse_step_b1",
            &mut store,
            &[DataArg::F32(&h0), DataArg::F32(&d)],
        )
        .unwrap();
    let (probs, h1) = (&outs[0], &outs[1]);
    assert_eq!(probs.len(), 12);
    assert_eq!(h1.len(), 64);
    assert!(h1.iter().any(|&x| x.abs() > 1e-6), "state must update");
    // Feeding h1 back changes the output (recurrence is live).
    let outs2 = rt
        .call(
            "aip_warehouse_step_b1",
            &mut store,
            &[DataArg::F32(h1), DataArg::F32(&d)],
        )
        .unwrap();
    assert_ne!(outs[0], outs2[0]);
}

#[test]
fn aip_training_reduces_loss_and_writes_back() {
    let Some(rt) = runtime() else { return };
    let mut store = rt.load_store("aip_traffic").unwrap();
    // Synthetic supervised task: u = first 4 bits of d.
    let mb = 256usize;
    let mut rng = ials::util::Pcg32::seeded(3);
    let lr = [1e-2f32];
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..30 {
        let mut d = vec![0.0f32; mb * 40];
        let mut y = vec![0.0f32; mb * 4];
        for r in 0..mb {
            for c in 0..40 {
                d[r * 40 + c] = if rng.bernoulli(0.5) { 1.0 } else { 0.0 };
            }
            for c in 0..4 {
                y[r * 4 + c] = d[r * 40 + c];
            }
        }
        let outs = rt
            .call(
                "aip_traffic_update",
                &mut store,
                &[DataArg::F32(&lr), DataArg::F32(&d), DataArg::F32(&y)],
            )
            .unwrap();
        let loss = outs[0][0];
        if first.is_none() {
            first = Some(loss);
        }
        last = loss;
    }
    assert!(store.get("adam_t").unwrap()[0] == 30.0, "adam step counter written back");
    assert!(last < first.unwrap() * 0.7, "loss should drop: {} -> {}", first.unwrap(), last);
    // The trained store must now predict the rule.
    let mut d = vec![0.0f32; 16 * 40];
    d[0] = 1.0; // row 0, bit 0 set
    let probs = rt.call("aip_traffic_fwd_b16", &mut store, &[DataArg::F32(&d)]).unwrap();
    assert!(probs[0][0] > probs[0][4 * 15], "p(u0 | bit set) should exceed an unset row");
}

#[test]
fn ppo_update_executes_and_mutates_params() {
    let Some(rt) = runtime() else { return };
    let mut store = rt.load_store("policy_traffic").unwrap();
    let norm_before = store.param_norm();
    let mb = 256usize;
    let obs = vec![0.1f32; mb * 42];
    let actions = vec![0i32; mb];
    let adv = vec![1.0f32; mb];
    let ret = vec![0.5f32; mb];
    // old_logp ~ ln(0.5) for a near-uniform initial 2-action policy.
    let old_logp = vec![(0.5f32).ln(); mb];
    let hyper: Vec<[f32; 1]> = vec![[3e-4], [0.2], [0.5], [0.01], [0.5]];
    let outs = rt
        .call(
            "policy_traffic_update",
            &mut store,
            &[
                DataArg::F32(&hyper[0]),
                DataArg::F32(&hyper[1]),
                DataArg::F32(&hyper[2]),
                DataArg::F32(&hyper[3]),
                DataArg::F32(&hyper[4]),
                DataArg::F32(&obs),
                DataArg::I32(&actions),
                DataArg::F32(&adv),
                DataArg::F32(&ret),
                DataArg::F32(&old_logp),
            ],
        )
        .unwrap();
    let stats = &outs[0];
    assert_eq!(stats.len(), 5);
    assert!(stats.iter().all(|x| x.is_finite()));
    assert!(store.param_norm() != norm_before, "params must change");
    assert_eq!(store.get("adam_t").unwrap()[0], 1.0);
}

#[test]
fn wrong_arity_and_shapes_rejected() {
    let Some(rt) = runtime() else { return };
    let mut store = rt.load_store("policy_traffic").unwrap();
    // missing args
    assert!(rt.call("policy_traffic_fwd_b16", &mut store, &[]).is_err());
    // wrong size
    let obs = vec![0.0f32; 3];
    assert!(rt.call("policy_traffic_fwd_b16", &mut store, &[DataArg::F32(&obs)]).is_err());
    // wrong model store
    let mut wrong = rt.load_store("aip_traffic").unwrap();
    let obs = vec![0.0f32; 16 * 42];
    assert!(rt.call("policy_traffic_fwd_b16", &mut wrong, &[DataArg::F32(&obs)]).is_err());
    // unknown artifact
    assert!(rt.call("nope", &mut store, &[]).is_err());
}

#[test]
fn geometry_matches_rust_simulators() {
    let Some(rt) = runtime() else { return };
    use ials::config::{TrafficConfig, WarehouseConfig};
    use ials::core::{Environment, GlobalEnv};
    let t = ials::sim::traffic::TrafficGlobalEnv::new(&TrafficConfig::default());
    assert_eq!(rt.geom("traffic_obs").unwrap(), t.obs_dim());
    assert_eq!(rt.geom("traffic_act").unwrap(), t.num_actions());
    assert_eq!(rt.geom("traffic_dset").unwrap(), t.dset_dim());
    assert_eq!(rt.geom("traffic_alsh").unwrap(), t.alsh_dim());
    assert_eq!(rt.geom("traffic_u").unwrap(), t.num_influence_sources());
    let w = ials::sim::warehouse::WarehouseGlobalEnv::new(&WarehouseConfig::default());
    assert_eq!(rt.geom("wh_obs").unwrap(), w.obs_dim());
    assert_eq!(rt.geom("wh_act").unwrap(), w.num_actions());
    assert_eq!(rt.geom("wh_dset").unwrap(), w.dset_dim());
    assert_eq!(rt.geom("wh_u").unwrap(), w.num_influence_sources());
}
