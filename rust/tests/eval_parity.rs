//! Eval-vs-training parity: `coordinator/evaluator.rs` drives a **batch-1
//! serial** environment with `Policy::forward1`, while training runs the
//! **fused** multi-env pipeline with batched forwards. Both must be the
//! same computation bit for bit — otherwise eval metrics could drift from
//! what training actually optimizes. Two facts make parity hold, and this
//! file pins both at once by lockstepping env 0 of a fused training env
//! against a batch-1 sandwich env at the same seed:
//!
//! * every env is seeded from its **global** index, so env 0 of a B-env
//!   batch and the single env of a batch-1 env live identical lives, and
//! * the native forward kernels compute rows independently, so `forward1`
//!   equals row 0 of the batched `forward_into` (and the batch-1 AIP call
//!   equals row 0 of the fused shard-local AIP forward).

use ials::config::{TrafficConfig, WarehouseConfig};
use ials::core::VecEnv;
use ials::ials::IalsVecEnv;
use ials::influence::NeuralAip;
use ials::rl::Policy;
use ials::runtime::{Runtime, SynthGeometry};
use ials::sim::traffic::TrafficLocalEnv;
use ials::sim::warehouse::WarehouseLocalEnv;
use std::rc::Rc;

const STEPS: usize = 210; // crosses the 200-step episode boundary

/// Lockstep a fused B-env training IALS against a batch-1 sandwich IALS
/// (the evaluator-style path) and a batched-vs-batch-1 policy forward.
fn assert_eval_parity(
    big: &mut dyn VecEnv,
    small: &mut dyn VecEnv,
    policy: &mut Policy,
    seed: u64,
    label: &str,
) {
    let b = big.num_envs();
    let d = big.obs_dim();
    let na = big.num_actions();
    assert_eq!(small.num_envs(), 1, "{label}: small side must be batch-1");
    assert_eq!(small.obs_dim(), d);
    big.reset_all(seed);
    small.reset_all(seed);
    let mut obs_big = vec![0.0f32; b * d];
    let mut obs_small = vec![0.0f32; d];
    let mut logits = vec![0.0f32; b * policy.act_dim];
    let mut values = vec![0.0f32; b];
    let mut row0 = vec![0.0f32; policy.act_dim];
    let (mut rb, mut rs) = (vec![0.0f32; b], [0.0f32; 1]);
    let (mut db, mut ds) = (vec![false; b], [false; 1]);
    let mut actions = vec![0usize; b];
    for t in 0..STEPS {
        big.observe_all(&mut obs_big);
        small.observe_all(&mut obs_small);
        assert_eq!(&obs_big[..d], &obs_small[..], "{label}: env-0 obs diverged at step {t}");

        // Batched training forward vs the batch-1 eval forward: row 0 must
        // be bitwise identical (the evaluator samples from these logits).
        policy.forward_into(&obs_big, &mut logits, &mut values).unwrap();
        row0.copy_from_slice(&logits[..policy.act_dim]);
        let v0 = values[0];
        let (l1, v1) = policy.forward1(&obs_small).unwrap();
        assert_eq!(l1, row0.as_slice(), "{label}: forward1 logits != batched row 0 at step {t}");
        assert_eq!(v1, v0, "{label}: forward1 value != batched row 0 at step {t}");

        for (i, a) in actions.iter_mut().enumerate() {
            *a = (t + i) % na;
        }
        big.step_all(&actions, &mut rb, &mut db);
        small.step_all(&actions[..1], &mut rs, &mut ds);
        assert_eq!(rb[0], rs[0], "{label}: env-0 reward diverged at step {t}");
        assert_eq!(db[0], ds[0], "{label}: env-0 done diverged at step {t}");
    }
}

#[test]
fn batch1_eval_path_matches_fused_training_env_traffic() {
    let b = 6;
    let geom = SynthGeometry { rollout_b: b, ..SynthGeometry::default() };
    let rt = Rc::new(Runtime::native(&geom));
    let cfg = TrafficConfig::default();

    let big_aip = NeuralAip::new(rt.clone(), "aip_traffic", b).unwrap();
    let mut big = IalsVecEnv::with_workers(
        (0..b).map(|_| TrafficLocalEnv::new(&cfg)).collect(),
        Box::new(big_aip),
        3,
    );
    assert!(big.is_fused(), "training env must run the fused pipeline");

    let small_aip = NeuralAip::new(rt.clone(), "aip_traffic", 1).unwrap();
    let mut small = IalsVecEnv::new(vec![TrafficLocalEnv::new(&cfg)], Box::new(small_aip));
    small.set_fused(false); // the serial coordinator-batched eval-style path

    let mut policy = Policy::new(rt, "policy_traffic", b).unwrap();
    policy.reinit(33).unwrap();
    assert_eval_parity(&mut big, &mut small, &mut policy, 5, "traffic");
}

#[test]
fn batch1_eval_path_matches_fused_training_env_warehouse_gru() {
    // The stateful case: row 0 of the fused env's GRU h band must evolve
    // exactly like the batch-1 predictor's whole state, across episode
    // resets.
    let b = 5;
    let geom = SynthGeometry { rollout_b: b, ..SynthGeometry::default() };
    let rt = Rc::new(Runtime::native(&geom));
    let cfg = WarehouseConfig::default();

    let big_aip = NeuralAip::new(rt.clone(), "aip_warehouse", b).unwrap();
    let mut big = IalsVecEnv::with_workers(
        (0..b).map(|_| WarehouseLocalEnv::new(&cfg)).collect(),
        Box::new(big_aip),
        2,
    );
    assert!(big.is_fused(), "training env must run the fused pipeline");

    let small_aip = NeuralAip::new(rt.clone(), "aip_warehouse", 1).unwrap();
    let mut small = IalsVecEnv::new(vec![WarehouseLocalEnv::new(&cfg)], Box::new(small_aip));
    small.set_fused(false);

    let mut policy = Policy::new(rt, "policy_warehouse_nm", b).unwrap();
    policy.reinit(34).unwrap();
    assert_eval_parity(&mut big, &mut small, &mut policy, 6, "warehouse");
}
