//! Cross-module traffic-domain integration (no artifacts needed):
//! controller quality orderings and GS↔LS structural agreement.

use ials::config::TrafficConfig;
use ials::core::{Environment, GlobalEnv};
use ials::sim::traffic::TrafficGlobalEnv;
use ials::util::Pcg32;

fn mean_reward(
    env: &mut TrafficGlobalEnv,
    episodes: usize,
    mut policy: impl FnMut(&TrafficGlobalEnv, &mut Pcg32) -> usize,
) -> f64 {
    let mut rng = Pcg32::seeded(4242);
    let mut total = 0.0f64;
    let mut steps = 0usize;
    for ep in 0..episodes {
        env.reset(1000 + ep as u64);
        loop {
            let a = policy(env, &mut rng);
            let s = env.step(a);
            total += s.reward as f64;
            steps += 1;
            if s.done {
                break;
            }
        }
    }
    total / steps as f64
}

/// The actuated controller (the paper's strong baseline) must clearly beat
/// both the always-keep and the uniform-random light policies.
#[test]
fn actuated_controller_beats_naive_policies() {
    let cfg = TrafficConfig::default();
    let mut env = TrafficGlobalEnv::new(&cfg);
    let actuated = mean_reward(&mut env, 3, |e, _| e.actuated_action());
    let random = mean_reward(&mut env, 3, |_, rng| rng.below(2));
    let never = mean_reward(&mut env, 3, |_, _| 0);
    assert!(actuated > random + 0.01, "actuated {actuated:.4} must beat random {random:.4}");
    assert!(actuated > never + 0.01, "actuated {actuated:.4} must beat never-switch {never:.4}");
}

/// Congestion responds to inflow: heavier boundary inflow lowers average
/// speed under the same controller.
#[test]
fn heavier_inflow_lowers_speed() {
    let light = {
        let mut cfg = TrafficConfig::default();
        cfg.inflow_prob = 0.05;
        let mut env = TrafficGlobalEnv::new(&cfg);
        mean_reward(&mut env, 3, |e, _| e.actuated_action())
    };
    let heavy = {
        let mut cfg = TrafficConfig::default();
        cfg.inflow_prob = 0.4;
        let mut env = TrafficGlobalEnv::new(&cfg);
        mean_reward(&mut env, 3, |e, _| e.actuated_action())
    };
    assert!(
        light > heavy + 0.02,
        "light traffic {light:.4} should flow faster than heavy {heavy:.4}"
    );
}

/// The influence marginals differ between the two highlighted
/// intersections (they are coupled differently to the network) — the
/// reason the paper trains separate AIPs for each (Fig 2 / Fig 10).
#[test]
fn intersections_have_different_influence_patterns() {
    let run = |which: usize| {
        let mut cfg = TrafficConfig::default();
        cfg.agent_intersection = which;
        let mut env = TrafficGlobalEnv::new(&cfg);
        let data = ials::collect::collect_dataset(
            &mut env,
            6000,
            7,
            ials::collect::FeatureKind::Dset,
        );
        data.u_marginals()
    };
    let m1 = run(1);
    let m2 = run(2);
    let diff: f32 = m1.iter().zip(&m2).map(|(a, b)| (a - b).abs()).sum();
    assert!(diff > 0.01, "marginals should differ: {m1:?} vs {m2:?}");
}

/// Substeps make the GS proportionally more expensive but leave the
/// interface identical (obs dims, action space, episode structure).
#[test]
fn substeps_preserve_interface() {
    for substeps in [1, 3, 6] {
        let mut cfg = TrafficConfig::default();
        cfg.substeps = substeps;
        let mut env = TrafficGlobalEnv::new(&cfg);
        env.reset(1);
        assert_eq!(env.obs_dim(), 42);
        let mut done = false;
        let mut n = 0;
        while !done {
            done = env.step(n % 2).done;
            n += 1;
        }
        assert_eq!(n, cfg.episode_len);
    }
}

/// d-set excludes the light phase: flipping the agent's lights (via
/// actions) must not directly alter the d-set encoding of the same car
/// configuration. (The observation *does* include phase.)
#[test]
fn dset_is_light_invariant_encoding() {
    let cfg = TrafficConfig::default();
    let mut env = TrafficGlobalEnv::new(&cfg);
    env.reset(3);
    let mut obs_a = vec![0.0; env.obs_dim()];
    let mut d_a = vec![0.0; env.dset_dim()];
    // Step past min green, then switch and compare d-set before/after the
    // same-state light flip... the cleanest observable: dset dim excludes
    // the 2 phase entries that obs carries.
    env.observe(&mut obs_a);
    env.dset(&mut d_a);
    assert_eq!(obs_a.len(), d_a.len() + 2);
    assert_eq!(&obs_a[..40], &d_a[..]);
}
