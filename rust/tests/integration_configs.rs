//! Shipped configuration files must parse, validate, and agree with the
//! compiled artifact geometry; plus failure-injection on the runtime and
//! config layers.

use ials::config::ExperimentConfig;

#[test]
fn all_shipped_configs_parse_and_validate() {
    let entries = std::fs::read_dir("configs").expect("configs/ missing");
    let mut n = 0;
    for e in entries {
        let path = e.unwrap().path();
        if path.extension().and_then(|s| s.to_str()) != Some("toml") {
            continue;
        }
        let cfg = ExperimentConfig::load(&path)
            .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        // Figure configs must keep the artifact-compatible batch geometry.
        assert_eq!(cfg.ppo.num_envs, 16, "{}", path.display());
        assert_eq!(cfg.ppo.rollout_len, 128, "{}", path.display());
        assert_eq!(cfg.ppo.minibatch, 256, "{}", path.display());
        n += 1;
    }
    assert!(n >= 7, "expected one config per figure, found {n}");
}

#[test]
fn config_name_matches_figure_harness() {
    for name in ials::coordinator::FIGURES {
        let path = format!("configs/{name}.toml");
        let cfg = ExperimentConfig::load(&path).unwrap();
        assert_eq!(&cfg.name, name, "{path}: name must match the harness figure id");
    }
}

#[test]
fn corrupted_manifest_is_rejected_cleanly() {
    let dir = std::env::temp_dir().join("ials_corrupt_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), "version 1\nartifact broken\n").unwrap();
    let err = match ials::runtime::Runtime::load(&dir) {
        Err(e) => e,
        Ok(_) => panic!("should fail"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("missing model") || msg.contains("artifact"), "{msg}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn missing_artifacts_dir_mentions_make_artifacts() {
    let err = match ials::runtime::Runtime::load("/nonexistent/path") {
        Err(e) => e,
        Ok(_) => panic!("should fail"),
    };
    assert!(format!("{err:#}").contains("make artifacts"));
}

#[test]
fn missing_hlo_file_fails_at_call_not_load() {
    // A manifest referencing a nonexistent HLO file loads fine (lazy
    // compile) but fails with a useful error on first call.
    let dir = std::env::temp_dir().join("ials_missing_hlo");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.txt"),
        "version 1\nmodel m\nparam w f32 2\nendmodel\n\
         artifact a\nmodel m\nhlo gone.hlo.txt\ninput param w\nendartifact\n",
    )
    .unwrap();
    std::fs::write(dir.join("m.params.bin"), [0u8; 8]).unwrap();
    let rt = ials::runtime::Runtime::load(&dir).unwrap();
    let mut store = rt.load_store("m").unwrap();
    let err = rt.call("a", &mut store, &[]).unwrap_err();
    assert!(format!("{err:#}").contains("gone.hlo.txt"));
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn truncated_params_bin_is_rejected() {
    let dir = std::env::temp_dir().join("ials_truncated_params");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.txt"),
        "version 1\nmodel m\nparam w f32 4\nendmodel\n",
    )
    .unwrap();
    std::fs::write(dir.join("m.params.bin"), [0u8; 7]).unwrap(); // needs 16
    let rt = ials::runtime::Runtime::load(&dir).unwrap();
    let err = rt.load_store("m").unwrap_err();
    assert!(format!("{err:#}").contains("expected 16"));
    std::fs::remove_dir_all(dir).ok();
}
