//! Sharded-execution determinism: the parallel executor must be a pure
//! performance optimization — `num_workers = 4` produces bitwise-identical
//! observations/rewards/dones to the serial `VecEnv` at the same seed, for
//! both domains' local sims (IALS) and for the sharded GS. The second half
//! pins the **fused step pipeline** (gather → shard-local AIP forward →
//! sampling → LS step in one dispatch) against the PR 3 sandwich
//! (gather → coordinator-batched AIP call → step) with real neural AIPs on
//! the native engine — fused must equal sandwich bitwise for every
//! `num_workers`, including counts that do not divide the batch.

use ials::config::{TrafficConfig, WarehouseConfig};
use ials::core::{shard_ranges, FrameStackVec, GsVecEnv, ShardedVecEnv, VecEnv};
use ials::ials::IalsVecEnv;
use ials::influence::{FixedMarginalAip, NeuralAip};
use ials::runtime::{Runtime, SynthGeometry};
use ials::sim::traffic::{TrafficGlobalEnv, TrafficLocalEnv};
use ials::sim::warehouse::WarehouseLocalEnv;
use ials::util::Pcg32;
use std::rc::Rc;

const STEPS: usize = 200;

/// Drive two envs with an identical action stream for `STEPS` steps and
/// assert bitwise-equal outputs each step.
fn assert_lockstep(a: &mut dyn VecEnv, b: &mut dyn VecEnv, seed: u64, label: &str) {
    assert_eq!(a.num_envs(), b.num_envs(), "{label}: batch mismatch");
    assert_eq!(a.obs_dim(), b.obs_dim(), "{label}: obs_dim mismatch");
    let bsz = a.num_envs();
    let d = a.obs_dim();
    let na = a.num_actions();
    a.reset_all(seed);
    b.reset_all(seed);
    let mut rng = Pcg32::new(seed, 777);
    let mut actions = vec![0usize; bsz];
    let (mut obs_a, mut obs_b) = (vec![0.0f32; bsz * d], vec![0.0f32; bsz * d]);
    let (mut ra, mut rb) = (vec![0.0f32; bsz], vec![0.0f32; bsz]);
    let (mut da, mut db) = (vec![false; bsz], vec![false; bsz]);

    a.observe_all(&mut obs_a);
    b.observe_all(&mut obs_b);
    assert_eq!(obs_a, obs_b, "{label}: initial observations diverged");

    for t in 0..STEPS {
        for act in actions.iter_mut() {
            *act = rng.below(na);
        }
        a.step_all(&actions, &mut ra, &mut da);
        b.step_all(&actions, &mut rb, &mut db);
        assert_eq!(ra, rb, "{label}: rewards diverged at step {t}");
        assert_eq!(da, db, "{label}: dones diverged at step {t}");
        a.observe_all(&mut obs_a);
        b.observe_all(&mut obs_b);
        assert_eq!(obs_a, obs_b, "{label}: observations diverged at step {t}");
    }
}

fn traffic_ials(b: usize, workers: usize) -> IalsVecEnv<TrafficLocalEnv> {
    let cfg = TrafficConfig::default();
    let envs: Vec<TrafficLocalEnv> = (0..b).map(|_| TrafficLocalEnv::new(&cfg)).collect();
    let aip = FixedMarginalAip::constant(b, 4 * cfg.lane_len, 4, 0.25);
    IalsVecEnv::with_workers(envs, Box::new(aip), workers)
}

fn warehouse_ials(b: usize, workers: usize) -> IalsVecEnv<WarehouseLocalEnv> {
    let cfg = WarehouseConfig::default();
    let envs: Vec<WarehouseLocalEnv> = (0..b).map(|_| WarehouseLocalEnv::new(&cfg)).collect();
    let aip = FixedMarginalAip::constant(b, 24, 12, 0.15);
    IalsVecEnv::with_workers(envs, Box::new(aip), workers)
}

#[test]
fn traffic_ials_sharded_equals_serial_over_200_steps() {
    let mut serial = traffic_ials(16, 1);
    let mut sharded = traffic_ials(16, 4);
    assert_eq!(sharded.num_shards(), 4);
    assert_lockstep(&mut serial, &mut sharded, 21, "traffic ials w=4");
}

#[test]
fn warehouse_ials_sharded_equals_serial_over_200_steps() {
    let mut serial = warehouse_ials(16, 1);
    let mut sharded = warehouse_ials(16, 4);
    assert_eq!(sharded.num_shards(), 4);
    assert_lockstep(&mut serial, &mut sharded, 22, "warehouse ials w=4");
}

#[test]
fn worker_count_is_output_invariant() {
    // Not just 1 vs 4: every worker count gives the same trajectory, even
    // when the batch does not divide evenly.
    let mut reference = traffic_ials(6, 1);
    for w in [2usize, 3, 4, 6, 8] {
        let mut sharded = traffic_ials(6, w);
        assert_lockstep(&mut reference, &mut sharded, 31, &format!("traffic ials w={w}"));
    }
}

#[test]
fn traffic_gs_sharded_equals_serial() {
    let cfg = TrafficConfig::default();
    let b = 8;
    let mut serial = GsVecEnv::new((0..b).map(|_| TrafficGlobalEnv::new(&cfg)).collect::<Vec<_>>());
    let shards: Vec<GsVecEnv<TrafficGlobalEnv>> = shard_ranges(b, 4)
        .into_iter()
        .map(|(s, e)| {
            GsVecEnv::with_index_offset(
                (s..e).map(|_| TrafficGlobalEnv::new(&cfg)).collect(),
                s,
            )
        })
        .collect();
    let mut sharded = ShardedVecEnv::from_shards(shards);
    assert_lockstep(&mut serial, &mut sharded, 23, "traffic gs w=4");
}

#[test]
fn frame_stack_over_sharded_equals_serial() {
    // Frame stacking composes with sharding: each shard writes straight
    // into the ring slab, and the stacked output still matches serial.
    let mut serial = FrameStackVec::new(warehouse_ials(8, 1), 4);
    let mut sharded = FrameStackVec::new(warehouse_ials(8, 3), 4);
    assert_lockstep(&mut serial, &mut sharded, 24, "framestack ials w=3");
}

// ---------------------------------------------------------------------------
// Fused pipeline vs the PR 3 sandwich, real neural AIPs
// ---------------------------------------------------------------------------

/// Traffic IALS with a real FNN AIP on the native engine, pipeline and
/// worker count selectable. The runtime is per-env so nothing is shared
/// between the two sides of a comparison.
fn traffic_neural_ials(b: usize, workers: usize, fused: bool) -> IalsVecEnv<TrafficLocalEnv> {
    let geom = SynthGeometry { rollout_b: b, ..SynthGeometry::default() };
    let rt = Rc::new(Runtime::native(&geom));
    let cfg = TrafficConfig::default();
    let envs: Vec<TrafficLocalEnv> = (0..b).map(|_| TrafficLocalEnv::new(&cfg)).collect();
    let aip = NeuralAip::new(rt, "aip_traffic", b).expect("FNN AIP");
    let mut env = IalsVecEnv::with_workers(envs, Box::new(aip), workers);
    env.set_fused(fused);
    assert_eq!(env.is_fused(), fused, "native FNN AIP must support both pipelines");
    env
}

/// Warehouse IALS with the recurrent GRU AIP (per-env hidden state — the
/// stateful case: the fused dispatch advances and episode-resets each
/// shard's own band of the h double-buffer).
fn warehouse_neural_ials(b: usize, workers: usize, fused: bool) -> IalsVecEnv<WarehouseLocalEnv> {
    let geom = SynthGeometry { rollout_b: b, ..SynthGeometry::default() };
    let rt = Rc::new(Runtime::native(&geom));
    let cfg = WarehouseConfig::default();
    let envs: Vec<WarehouseLocalEnv> = (0..b).map(|_| WarehouseLocalEnv::new(&cfg)).collect();
    let aip = NeuralAip::new(rt, "aip_warehouse", b).expect("GRU AIP");
    let mut env = IalsVecEnv::with_workers(envs, Box::new(aip), workers);
    env.set_fused(fused);
    assert_eq!(env.is_fused(), fused, "native GRU AIP must support both pipelines");
    env
}

#[test]
fn fused_fnn_ials_equals_sandwich_for_any_worker_count() {
    // Reference: the PR 3 sandwich, serial. The fused pipeline must match
    // it bitwise for every worker count — 4 divides the batch of 16, 3 and
    // 5 do not, 1 is the fused-but-inline case.
    let mut sandwich = traffic_neural_ials(16, 1, false);
    for w in [1usize, 3, 4, 5] {
        let mut fused = traffic_neural_ials(16, w, true);
        assert_lockstep(&mut sandwich, &mut fused, 41, &format!("fused fnn ials w={w}"));
    }
}

#[test]
fn fused_gru_ials_equals_sandwich_across_episode_boundaries() {
    // 210 > episode_len = 200, so the comparison crosses an auto-reset:
    // the fused path's in-dispatch h-row clearing must line up with the
    // sandwich's coordinator-side reset_state.
    let steps = 210;
    let b = 8;
    let mut sandwich = warehouse_neural_ials(b, 1, false);
    for w in [3usize, 4] {
        let mut fused = warehouse_neural_ials(b, w, true);
        sandwich.reset_all(42);
        fused.reset_all(42);
        let mut rng = Pcg32::new(42, 777);
        let na = sandwich.num_actions();
        let d = sandwich.obs_dim();
        let mut actions = vec![0usize; b];
        let (mut ra, mut rb) = (vec![0.0f32; b], vec![0.0f32; b]);
        let (mut da, mut db) = (vec![false; b], vec![false; b]);
        let (mut oa, mut ob) = (vec![0.0f32; b * d], vec![0.0f32; b * d]);
        for t in 0..steps {
            for a in actions.iter_mut() {
                *a = rng.below(na);
            }
            sandwich.step_all(&actions, &mut ra, &mut da);
            fused.step_all(&actions, &mut rb, &mut db);
            assert_eq!(ra, rb, "w={w}: rewards diverged at step {t}");
            assert_eq!(da, db, "w={w}: dones diverged at step {t}");
            sandwich.observe_all(&mut oa);
            fused.observe_all(&mut ob);
            assert_eq!(oa, ob, "w={w}: observations diverged at step {t}");
        }
    }
}

#[test]
fn fused_fixed_marginal_ials_sweep_is_pipeline_and_worker_invariant() {
    // The fixed-marginal predictor also shard-executes; sweep worker
    // counts (incl. non-dividing) against the serial sandwich.
    let mut reference = traffic_ials(6, 1);
    reference.set_fused(false);
    for w in [1usize, 2, 3, 4, 6, 8] {
        let mut fused = traffic_ials(6, w);
        assert!(fused.is_fused());
        assert_lockstep(&mut reference, &mut fused, 43, &format!("fused f-ials w={w}"));
    }
}
