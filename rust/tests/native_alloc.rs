//! Allocation audit of the native forward hot path: after a warmup call
//! (which builds the per-artifact scratch once), policy `forward_into` and
//! AIP `predict` must perform **zero heap allocations per step**. Pinned
//! with a counting global allocator; everything lives in one `#[test]` so
//! no parallel test can pollute the counter.

use ials::influence::{InfluencePredictor, NeuralAip};
use ials::rl::Policy;
use ials::runtime::Runtime;
use std::alloc::{GlobalAlloc, Layout, System};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn counted(f: impl FnOnce()) -> u64 {
    ALLOCS.store(0, Ordering::SeqCst);
    COUNTING.store(true, Ordering::SeqCst);
    f();
    COUNTING.store(false, Ordering::SeqCst);
    ALLOCS.load(Ordering::SeqCst)
}

#[test]
fn native_forward_hot_path_allocates_nothing() {
    let rt = Rc::new(Runtime::native_default());

    // Policy batched forward (the rollout hot path).
    let mut policy = Policy::new(rt.clone(), "policy_traffic", 16).unwrap();
    let obs = vec![0.25f32; 16 * 42];
    let mut logits = vec![0.0f32; 16 * 2];
    let mut values = vec![0.0f32; 16];
    for _ in 0..3 {
        policy.forward_into(&obs, &mut logits, &mut values).unwrap();
    }
    let n = counted(|| {
        for _ in 0..100 {
            policy.forward_into(&obs, &mut logits, &mut values).unwrap();
        }
    });
    assert_eq!(n, 0, "policy forward_into allocated {n} times in 100 steps");

    // Batch-1 eval forward (GS evaluation path).
    let obs1 = vec![0.25f32; 42];
    policy.forward1(&obs1).unwrap();
    let n = counted(|| {
        for _ in 0..100 {
            policy.forward1(&obs1).unwrap();
        }
    });
    assert_eq!(n, 0, "policy forward1 allocated {n} times in 100 steps");

    // FNN AIP predict.
    let mut fnn = NeuralAip::new(rt.clone(), "aip_traffic", 16).unwrap();
    let dsets = vec![0.5f32; 16 * 40];
    let mut probs = vec![0.0f32; 16 * 4];
    fnn.predict(&dsets, &mut probs).unwrap();
    let n = counted(|| {
        for _ in 0..100 {
            fnn.predict(&dsets, &mut probs).unwrap();
        }
    });
    assert_eq!(n, 0, "FNN AIP predict allocated {n} times in 100 steps");

    // Recurrent (GRU) AIP predict, including the h/h_next double-buffer swap.
    let mut gru = NeuralAip::new(rt, "aip_warehouse", 16).unwrap();
    let wdsets = vec![0.5f32; 16 * 24];
    let mut wprobs = vec![0.0f32; 16 * 12];
    gru.predict(&wdsets, &mut wprobs).unwrap();
    let n = counted(|| {
        for _ in 0..100 {
            gru.predict(&wdsets, &mut wprobs).unwrap();
        }
    });
    assert_eq!(n, 0, "GRU AIP predict allocated {n} times in 100 steps");
    assert!(wprobs.iter().all(|&p| (0.0..=1.0).contains(&p)));
}
