//! Allocation audit of the native hot paths: after a warmup call (which
//! builds the per-artifact scratch — including per-slice gradient scratch
//! and the cached Adam slot indices — once), policy `forward_into` / AIP
//! `predict`, the **fused IALS step** (one-dispatch gather → shard-local
//! AIP forward → influence sampling → LS step; per-shard `EngineScratch`
//! is allocated at env construction) **and the whole training path**
//! (fused whole-phase PPO update, FNN BCE step, GRU BPTT step) must
//! perform **zero steady-state heap allocations**, on both the serial and
//! the data-parallel engine (pool dispatch broadcasts a borrowed pointer —
//! no boxed jobs). Pinned with a counting global allocator; everything
//! lives in one `#[test]` so no parallel test can pollute the counter.

use ials::config::{PpoConfig, TrafficConfig, WarehouseConfig};
use ials::core::VecEnv;
use ials::ials::IalsVecEnv;
use ials::influence::{InfluencePredictor, NeuralAip};
use ials::rl::Policy;
use ials::runtime::{DataArg, Runtime, SynthGeometry};
use ials::sim::traffic::TrafficLocalEnv;
use ials::sim::warehouse::WarehouseLocalEnv;
use std::alloc::{GlobalAlloc, Layout, System};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn counted(f: impl FnOnce()) -> u64 {
    ALLOCS.store(0, Ordering::SeqCst);
    COUNTING.store(true, Ordering::SeqCst);
    f();
    COUNTING.store(false, Ordering::SeqCst);
    ALLOCS.load(Ordering::SeqCst)
}

#[test]
fn native_forward_hot_path_allocates_nothing() {
    let rt = Rc::new(Runtime::native_default());

    // Policy batched forward (the rollout hot path).
    let mut policy = Policy::new(rt.clone(), "policy_traffic", 16).unwrap();
    let obs = vec![0.25f32; 16 * 42];
    let mut logits = vec![0.0f32; 16 * 2];
    let mut values = vec![0.0f32; 16];
    for _ in 0..3 {
        policy.forward_into(&obs, &mut logits, &mut values).unwrap();
    }
    let n = counted(|| {
        for _ in 0..100 {
            policy.forward_into(&obs, &mut logits, &mut values).unwrap();
        }
    });
    assert_eq!(n, 0, "policy forward_into allocated {n} times in 100 steps");

    // Batch-1 eval forward (GS evaluation path).
    let obs1 = vec![0.25f32; 42];
    policy.forward1(&obs1).unwrap();
    let n = counted(|| {
        for _ in 0..100 {
            policy.forward1(&obs1).unwrap();
        }
    });
    assert_eq!(n, 0, "policy forward1 allocated {n} times in 100 steps");

    // FNN AIP predict.
    let mut fnn = NeuralAip::new(rt.clone(), "aip_traffic", 16).unwrap();
    let dsets = vec![0.5f32; 16 * 40];
    let mut probs = vec![0.0f32; 16 * 4];
    fnn.predict(&dsets, &mut probs).unwrap();
    let n = counted(|| {
        for _ in 0..100 {
            fnn.predict(&dsets, &mut probs).unwrap();
        }
    });
    assert_eq!(n, 0, "FNN AIP predict allocated {n} times in 100 steps");

    // Recurrent (GRU) AIP predict, including the h/h_next double-buffer swap.
    let mut gru = NeuralAip::new(rt, "aip_warehouse", 16).unwrap();
    let wdsets = vec![0.5f32; 16 * 24];
    let mut wprobs = vec![0.0f32; 16 * 12];
    gru.predict(&wdsets, &mut wprobs).unwrap();
    let n = counted(|| {
        for _ in 0..100 {
            gru.predict(&wdsets, &mut wprobs).unwrap();
        }
    });
    assert_eq!(n, 0, "GRU AIP predict allocated {n} times in 100 steps");
    assert!(wprobs.iter().all(|&p| (0.0..=1.0).contains(&p)));

    // ---- Fused IALS step: gather → shard-local AIP forward → influence
    // sampling → LS step, one pool dispatch per step. EngineScratch lives
    // on each shard from construction, so the steady state allocates
    // nothing — serial executor and pooled shards alike. (60 steps stay
    // inside the 200-step episodes: auto-reset is not under audit here.)
    for workers in [1usize, 2] {
        let rt = Rc::new(Runtime::native_default());
        let label = format!("fused ials num_workers={workers}");
        let tcfg = TrafficConfig::default();
        let envs: Vec<TrafficLocalEnv> = (0..16).map(|_| TrafficLocalEnv::new(&tcfg)).collect();
        let aip = NeuralAip::new(rt.clone(), "aip_traffic", 16).unwrap();
        let mut ials = IalsVecEnv::with_workers(envs, Box::new(aip), workers);
        assert!(ials.is_fused(), "[{label}] native FNN AIP must fuse");
        ials.reset_all(9);
        let actions = vec![0usize; 16];
        let mut rewards = vec![0.0f32; 16];
        let mut dones = vec![false; 16];
        for _ in 0..3 {
            ials.step_all(&actions, &mut rewards, &mut dones);
        }
        let n = counted(|| {
            for _ in 0..60 {
                ials.step_all(&actions, &mut rewards, &mut dones);
            }
        });
        assert_eq!(n, 0, "[{label}] fused FNN IALS step allocated {n} times in 60 steps");

        // Recurrent variant: the fused dispatch advances each shard's own
        // band of the GRU h double-buffer (swap on the coordinator).
        let wcfg = WarehouseConfig::default();
        let wenvs: Vec<WarehouseLocalEnv> =
            (0..16).map(|_| WarehouseLocalEnv::new(&wcfg)).collect();
        let gaip = NeuralAip::new(rt, "aip_warehouse", 16).unwrap();
        let mut wials = IalsVecEnv::with_workers(wenvs, Box::new(gaip), workers);
        assert!(wials.is_fused(), "[{label}] native GRU AIP must fuse");
        wials.reset_all(10);
        let wactions = vec![1usize; 16];
        for _ in 0..3 {
            wials.step_all(&wactions, &mut rewards, &mut dones);
        }
        let n = counted(|| {
            for _ in 0..60 {
                wials.step_all(&wactions, &mut rewards, &mut dones);
            }
        });
        assert_eq!(n, 0, "[{label}] fused GRU IALS step allocated {n} times in 60 steps");
    }

    // ---- Training path: fused PPO + FNN BCE + GRU BPTT, serial and
    // data-parallel (per-worker gradient scratch is preallocated at op
    // build; pool dispatch is allocation-free by construction). ----
    let geom = SynthGeometry {
        rollout_b: 4,
        rollout_t: 32,
        ppo_epochs: 2,
        ppo_minibatch: 32,
        aip_batch: 64,
        gru_seq_b: 8,
        gru_seq_t: 8,
        ..SynthGeometry::default()
    };
    for nn_workers in [1usize, 2] {
        let label = format!("nn_workers={nn_workers}");
        // Pool threads (if any) spawn here, before counting starts.
        let rt = Rc::new(if nn_workers == 1 {
            Runtime::native(&geom)
        } else {
            Runtime::native_parallel(&geom, nn_workers)
        });

        // Fused whole-phase PPO update (all epochs × minibatches, one call).
        let n_rows = 4 * 32;
        let cfg = PpoConfig {
            num_envs: 4,
            rollout_len: 32,
            epochs: 2,
            minibatch: 32,
            ..PpoConfig::default()
        };
        let mut policy = Policy::new(rt.clone(), "policy_traffic", 4).unwrap();
        let mut perm: Vec<i32> = Vec::with_capacity(2 * n_rows);
        for _ in 0..2 {
            perm.extend(0..n_rows as i32);
        }
        let p_obs = vec![0.25f32; n_rows * 42];
        let p_act: Vec<i32> = (0..n_rows as i32).map(|i| i % 2).collect();
        let p_adv = vec![0.5f32; n_rows];
        let p_ret = vec![0.25f32; n_rows];
        let p_lp = vec![(0.5f32).ln(); n_rows];
        for _ in 0..2 {
            policy.update_fused(&cfg, &perm, &p_obs, &p_act, &p_adv, &p_ret, &p_lp).unwrap();
        }
        let n = counted(|| {
            for _ in 0..3 {
                policy.update_fused(&cfg, &perm, &p_obs, &p_act, &p_adv, &p_ret, &p_lp).unwrap();
            }
        });
        assert_eq!(n, 0, "[{label}] fused PPO update allocated {n} times in 3 phases");

        // FNN BCE training step.
        let mut fnn_store = rt.load_store("aip_traffic").unwrap();
        let lr = [1e-3f32];
        let f_d = vec![0.5f32; 64 * 40];
        let f_y = vec![1.0f32; 64 * 4];
        let mut loss = [0.0f32; 1];
        for _ in 0..2 {
            rt.call_into(
                "aip_traffic_update",
                &mut fnn_store,
                &[DataArg::F32(&lr), DataArg::F32(&f_d), DataArg::F32(&f_y)],
                &mut [loss.as_mut_slice()],
            )
            .unwrap();
        }
        let n = counted(|| {
            for _ in 0..5 {
                rt.call_into(
                    "aip_traffic_update",
                    &mut fnn_store,
                    &[DataArg::F32(&lr), DataArg::F32(&f_d), DataArg::F32(&f_y)],
                    &mut [loss.as_mut_slice()],
                )
                .unwrap();
            }
        });
        assert_eq!(n, 0, "[{label}] FNN BCE update allocated {n} times in 5 steps");

        // GRU BPTT training step.
        let mut gru_store = rt.load_store("aip_warehouse").unwrap();
        let g_seqs = vec![0.5f32; 8 * 8 * 24];
        let g_y = vec![0.0f32; 8 * 8 * 12];
        for _ in 0..2 {
            rt.call_into(
                "aip_warehouse_update",
                &mut gru_store,
                &[DataArg::F32(&lr), DataArg::F32(&g_seqs), DataArg::F32(&g_y)],
                &mut [loss.as_mut_slice()],
            )
            .unwrap();
        }
        let n = counted(|| {
            for _ in 0..5 {
                rt.call_into(
                    "aip_warehouse_update",
                    &mut gru_store,
                    &[DataArg::F32(&lr), DataArg::F32(&g_seqs), DataArg::F32(&g_y)],
                    &mut [loss.as_mut_slice()],
                )
                .unwrap();
            }
        });
        assert_eq!(n, 0, "[{label}] GRU BPTT update allocated {n} times in 5 steps");
        assert!(loss[0].is_finite());
    }
}
