//! Acceptance tests for the fault-tolerant cross-process runtime
//! (`coordinator::distributed`):
//!
//! 1. A clean `--distributed N` run reproduces the in-process
//!    `num_learners = K` run **bitwise** — curves, AIP cross-entropy and
//!    final policy parameters — at the same seed.
//! 2. So does a run whose worker is killed mid-training (fault-injection
//!    hook): the supervisor restarts it, the worker resumes from its
//!    newest checkpoint, and no bit changes.
//! 3. A hung worker (alive, heartbeat frozen) is detected via the
//!    progress-based heartbeat timeout, killed and restarted — same
//!    bitwise outcome.
//! 4. A worker that crashes on every incarnation exhausts `max_restarts`:
//!    its shard is reported failed, the *other* shards still finish (and
//!    still match the reference bitwise), and the binary exits nonzero
//!    with the per-shard report.
//!
//! Wall-clock fields (`wall_clock_s`, `prep_secs`, `train_secs`) measure
//! real time and are excluded, as in every determinism test of the repo.

use ials::config::{BackendKind, DomainKind, ExperimentConfig, SimulatorKind};
use ials::coordinator::{
    run_distributed, run_multi_condition_resumable, DistributedOptions, MultiLearnerOutcome,
};
use ials::metrics::CurvePoint;
use ials::nn::ParamStore;
use ials::runtime::Runtime;
use ials::testkit::fault::{HANG_ENV, KILL_ENV};
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// Per-learner env steps in one PPO iteration of [`test_cfg`] runs.
const PER_ITER: usize = 8 * 16;

/// Small fig3-style traffic IALS config (the `checkpoint_resume.rs`
/// shape): 8 envs × 16 rollout, 3 PPO iterations, native backend, fast
/// restart backoff.
fn test_cfg(num_learners: usize, ckpt_dir: &Path) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "dist".into();
    cfg.domain = DomainKind::Traffic;
    cfg.simulator = SimulatorKind::Ials;
    cfg.num_learners = num_learners;
    cfg.seeds = vec![7];
    cfg.eval_every = 4096;
    cfg.eval_episodes = 1;
    cfg.ppo.num_envs = 8;
    cfg.ppo.rollout_len = 16;
    cfg.ppo.epochs = 2;
    cfg.ppo.minibatch = 32;
    cfg.ppo.total_steps = 3 * PER_ITER;
    cfg.aip.dataset_size = 1200;
    cfg.aip.eval_size = 800;
    cfg.aip.train_epochs = 1;
    cfg.aip.batch = 64;
    cfg.runtime.backend = BackendKind::Native;
    cfg.checkpoint_every = PER_ITER;
    cfg.checkpoint_dir = ckpt_dir.to_str().unwrap().to_string();
    cfg.distributed.backoff_ms = 50;
    cfg.validate().unwrap();
    cfg
}

/// Fresh per-test root under the system temp dir.
fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ials_distributed_{tag}"));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Coordinator options pointing at the real `repro` binary, with fault
/// env vars scoped to the spawned workers only (never this test process).
fn opts(env: &[(&str, &str)]) -> DistributedOptions {
    DistributedOptions {
        worker_exe: Some(PathBuf::from(env!("CARGO_BIN_EXE_repro"))),
        worker_env: env.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect(),
    }
}

/// The bit-comparable content of a learning curve (wall-clock excluded).
#[allow(clippy::type_complexity)]
fn curve_bits(curve: &[CurvePoint]) -> Vec<(usize, u64, u64, [u32; 7], usize)> {
    curve
        .iter()
        .map(|p| {
            (
                p.env_steps,
                p.eval_mean.to_bits(),
                p.eval_std.to_bits(),
                [
                    p.stats.total_loss.to_bits(),
                    p.stats.pg_loss.to_bits(),
                    p.stats.v_loss.to_bits(),
                    p.stats.entropy.to_bits(),
                    p.stats.approx_kl.to_bits(),
                    p.stats.grad_norm.to_bits(),
                    p.stats.rollout_reward.to_bits(),
                ],
                p.stats.episodes,
            )
        })
        .collect()
}

/// Named parameter tensors as bits, for exact comparison.
fn param_bits(pairs: &[(String, Vec<f32>)]) -> Vec<(String, Vec<u32>)> {
    pairs
        .iter()
        .map(|(n, v)| (n.clone(), v.iter().map(|x| x.to_bits()).collect()))
        .collect()
}

fn store_pairs(store: &ParamStore) -> Vec<(String, Vec<f32>)> {
    store.names().iter().map(|n| (n.clone(), store.get(n).unwrap().to_vec())).collect()
}

/// The uninterrupted in-process reference run for `k` learners.
fn reference(k: usize, tag: &str, seed: u64) -> MultiLearnerOutcome {
    let dir = tmp_dir(tag);
    let cfg = test_cfg(k, &dir);
    let rt = Rc::new(Runtime::from_config(&cfg).unwrap());
    let out = run_multi_condition_resumable(&rt, &cfg, seed, false, None).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    out
}

/// Assert learner `l` of the distributed outcome matches the reference
/// learner bitwise (curve, AIP cross-entropy, final policy parameters).
fn assert_learner_matches(
    out: &ials::coordinator::DistributedOutcome,
    reference: &MultiLearnerOutcome,
    l: usize,
    what: &str,
) {
    let lr = out.learners[l].as_ref().unwrap_or_else(|| panic!("{what}: learner {l} missing"));
    assert_eq!(
        curve_bits(&lr.result.curve),
        curve_bits(&reference.results[l].curve),
        "{what}: learner {l} curve diverged"
    );
    assert_eq!(
        lr.result.aip_ce.to_bits(),
        reference.results[l].aip_ce.to_bits(),
        "{what}: learner {l} AIP cross-entropy diverged"
    );
    assert_eq!(
        param_bits(&lr.policy_params),
        param_bits(&store_pairs(&reference.policy_stores[l])),
        "{what}: learner {l} final policy parameters diverged"
    );
}

/// Clean 2-process run over 3 learners == in-process run, bit for bit.
#[test]
fn clean_distributed_run_matches_in_process_bitwise() {
    let seed = 7u64;
    let reference = reference(3, "clean_ref", seed);
    let dir = tmp_dir("clean");
    let cfg = test_cfg(3, &dir);
    let out = run_distributed(&cfg, seed, 2, &opts(&[])).unwrap();
    assert!(out.all_ok(), "clean run must not degrade:\n{}", out.report());
    assert_eq!(out.shards.len(), 2);
    assert!(out.shards.iter().all(|s| s.restarts == 0), "clean run must not restart");
    for l in 0..3 {
        assert_learner_matches(&out, &reference, l, "clean");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Worker 0 is killed (process abort) right after iteration 2 of 3; the
/// supervisor restarts it, it resumes from its newest checkpoint, and the
/// final bits still match the in-process run.
#[test]
fn killed_worker_restarts_from_checkpoint_and_matches_bitwise() {
    let seed = 7u64;
    let reference = reference(3, "kill_ref", seed);
    let dir = tmp_dir("kill");
    let cfg = test_cfg(3, &dir);
    let out = run_distributed(&cfg, seed, 2, &opts(&[(KILL_ENV, "0:2")])).unwrap();
    assert!(out.all_ok(), "restarted run must finish:\n{}", out.report());
    assert_eq!(out.shards[0].restarts, 1, "worker 0 must have been restarted exactly once");
    assert_eq!(out.shards[1].restarts, 0);
    for l in 0..3 {
        assert_learner_matches(&out, &reference, l, "kill+restart");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// A hung worker (alive but frozen after iteration 1) trips the
/// progress-based heartbeat timeout, is killed and restarted, and the run
/// still matches the reference bitwise.
#[test]
fn hung_worker_is_detected_killed_and_restarted() {
    let seed = 7u64;
    let reference = reference(2, "hang_ref", seed);
    let dir = tmp_dir("hang");
    let mut cfg = test_cfg(2, &dir);
    cfg.distributed.heartbeat_timeout_secs = 6.0;
    let out = run_distributed(&cfg, seed, 2, &opts(&[(HANG_ENV, "1:1")])).unwrap();
    assert!(out.all_ok(), "restarted run must finish:\n{}", out.report());
    assert_eq!(out.shards[1].restarts, 1, "worker 1 must have been killed as hung and restarted");
    for l in 0..2 {
        assert_learner_matches(&out, &reference, l, "hang+restart");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Worker 0 crashes on *every* incarnation: after `max_restarts` the
/// shard is marked failed, but worker 1's shard finishes, still matches
/// the reference bitwise, and the report names the failure.
#[test]
fn exhausted_restarts_fail_the_shard_but_others_finish() {
    let seed = 7u64;
    let reference = reference(3, "exhaust_ref", seed);
    let dir = tmp_dir("exhaust");
    let mut cfg = test_cfg(3, &dir);
    cfg.distributed.max_restarts = 1;
    let out = run_distributed(&cfg, seed, 2, &opts(&[(KILL_ENV, "0:1:every")])).unwrap();
    assert!(!out.all_ok(), "shard 0 must be reported failed:\n{}", out.report());
    assert!(!out.shards[0].ok);
    assert_eq!(out.shards[0].restarts, 1, "the restart budget must be spent before failing");
    assert!(
        out.shards[0].error.as_deref().unwrap_or("").contains("exited abnormally"),
        "failure reason must name the crash: {:?}",
        out.shards[0].error
    );
    // shard_ranges(3, 2) = [(0, 2), (2, 3)]: learners 0 and 1 are lost,
    // learner 2 (worker 1) finishes and matches.
    assert!(out.learners[0].is_none() && out.learners[1].is_none());
    assert!(out.shards[1].ok);
    assert_learner_matches(&out, &reference, 2, "degraded");
    let report = out.report();
    assert!(report.contains("worker 0 (learners 0..2, 1 restart(s)): FAILED"), "{report}");
    assert!(report.contains("worker 1 (learners 2..3, 0 restart(s)): ok"), "{report}");
    std::fs::remove_dir_all(&dir).ok();
}

/// `--resume` makes no sense with `--distributed` (workers always
/// auto-resume); the binary rejects the combination up front.
#[test]
fn binary_rejects_resume_with_distributed() {
    let dir = tmp_dir("cli_resume");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = test_cfg(2, &dir);
    let cfg_path = dir.join("cfg.toml");
    std::fs::write(&cfg_path, cfg.to_toml_string()).unwrap();
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["train", "--config", cfg_path.to_str().unwrap(), "--distributed", "2", "--resume"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "--distributed --resume must be rejected");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--resume is meaningless with --distributed"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

/// End-to-end degraded run through the binary: a crash-looping worker
/// with a zero restart budget fails its shard, the surviving learner's
/// curve CSV is still written, the per-shard report is printed, and the
/// exit code is nonzero.
#[test]
fn binary_degraded_run_exits_nonzero_with_report() {
    let seed = 7u64;
    let dir = tmp_dir("cli_degraded");
    std::fs::create_dir_all(&dir).unwrap();
    let mut cfg = test_cfg(2, &dir);
    cfg.distributed.max_restarts = 0;
    cfg.results_dir = dir.join("results").to_str().unwrap().to_string();
    cfg.validate().unwrap();
    std::fs::create_dir_all(&cfg.results_dir).unwrap();
    let cfg_path = dir.join("cfg.toml");
    std::fs::write(&cfg_path, cfg.to_toml_string()).unwrap();
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["train", "--config", cfg_path.to_str().unwrap(), "--distributed", "2"])
        .arg("--seed")
        .arg(seed.to_string())
        // The coordinator's environment is inherited by its workers.
        .env(KILL_ENV, "0:1:every")
        .output()
        .unwrap();
    assert!(!out.status.success(), "degraded run must exit nonzero");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("shard report:"), "{stdout}");
    assert!(stdout.contains("FAILED"), "{stdout}");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("distributed run degraded"), "{err}");
    let csv = Path::new(&cfg.results_dir).join(format!("ials-dist_seed{seed}_learner1.csv"));
    assert!(csv.is_file(), "surviving learner's curve CSV missing: {}", csv.display());
    let csv0 = Path::new(&cfg.results_dir).join(format!("ials-dist_seed{seed}_learner0.csv"));
    assert!(!csv0.exists(), "failed learner must not leave a curve CSV");
    std::fs::remove_dir_all(&dir).ok();
}
