//! End-to-end RL integration: PPO through the compiled artifacts must
//! actually *learn*. Uses a 2-armed bandit dressed in the traffic
//! observation geometry so the real `policy_traffic_*` artifacts apply.

use ials::config::{ExperimentConfig, PpoConfig, SimulatorKind};
use ials::core::{Environment, GsVecEnv, Step, VecEnv};
use ials::coordinator::evaluate;
use ials::rl::{Policy, PpoTrainer};
use ials::runtime::Runtime;
use ials::util::Pcg32;
use std::rc::Rc;

/// 2-armed bandit with traffic-shaped observations (obs_dim 42, 2 actions):
/// action 1 pays 0.8 in expectation, action 0 pays 0.2.
struct Bandit {
    rng: Pcg32,
    t: usize,
}

impl Environment for Bandit {
    fn obs_dim(&self) -> usize {
        42
    }
    fn num_actions(&self) -> usize {
        2
    }
    fn reset(&mut self, seed: u64) {
        self.rng = Pcg32::seeded(seed);
        self.t = 0;
    }
    fn observe(&self, out: &mut [f32]) {
        out.fill(0.0);
        out[0] = 1.0;
    }
    fn step(&mut self, action: usize) -> Step {
        self.t += 1;
        let p = if action == 1 { 0.8 } else { 0.2 };
        let reward = if self.rng.bernoulli(p) { 1.0 } else { 0.0 };
        Step { reward, done: self.t >= 32 }
    }
}

fn runtime() -> Option<Rc<Runtime>> {
    // Compiled artifacts when present, the native CPU backend otherwise —
    // PPO-learns tests execute either way.
    Some(Rc::new(Runtime::load_or_native("artifacts").expect("runtime")))
}

#[test]
fn ppo_learns_the_better_arm() {
    let Some(rt) = runtime() else { return };
    let mut policy = Policy::new(rt.clone(), "policy_traffic", 16).unwrap();
    policy.reinit(7).unwrap();
    let cfg = PpoConfig { lr: 1e-3, ..PpoConfig::default() };
    let mut trainer = PpoTrainer::new(&cfg, 42, 7);
    let mut env = GsVecEnv::new((0..16).map(|_| Bandit { rng: Pcg32::seeded(0), t: 0 }).collect());
    env.reset_all(7);

    let mut first = None;
    let mut last = 0.0f32;
    for _ in 0..25 {
        let stats = trainer.train_iteration(&mut env, &mut policy).unwrap();
        if first.is_none() {
            first = Some(stats.rollout_reward);
        }
        last = stats.rollout_reward;
    }
    let first = first.unwrap();
    assert!(
        (0.35..0.65).contains(&first),
        "initial policy should be near-uniform (reward ~0.5), got {first}"
    );
    assert!(last > 0.7, "PPO should find the 0.8 arm: {first} -> {last}");
}

#[test]
fn evaluation_runs_on_the_gs() {
    let Some(rt) = runtime() else { return };
    let mut policy = Policy::new(rt.clone(), "policy_traffic", 16).unwrap();
    let cfg = ExperimentConfig::default();
    let mut eval_env = ials::coordinator::experiment::make_eval_env(&cfg);
    let r = evaluate(eval_env.as_mut(), &mut policy, 2, 3).unwrap();
    assert_eq!(r.episodes, 2);
    assert!((0.0..=1.0).contains(&r.mean), "traffic reward in [0,1]: {}", r.mean);
}

#[test]
fn run_condition_ials_smoke() {
    let Some(rt) = runtime() else { return };
    let mut cfg = ExperimentConfig::default();
    cfg.name = "smoke".into();
    cfg.simulator = SimulatorKind::Ials;
    cfg.aip.dataset_size = 2048;
    cfg.aip.train_epochs = 1;
    cfg.ppo.total_steps = 4096;
    cfg.eval_every = 2048;
    cfg.eval_episodes = 1;
    let r = ials::coordinator::run_condition(&rt, &cfg, 1).unwrap();
    assert!(r.prep_secs > 0.0, "AIP prep must be timed");
    assert!(r.train_secs > 0.0);
    assert!(r.aip_ce.is_finite());
    assert!(r.curve.len() >= 2, "initial + at least one eval point");
    assert!(r.curve.windows(2).all(|w| w[0].wall_clock_s <= w[1].wall_clock_s));
    assert!(r.curve[0].wall_clock_s >= r.prep_secs, "curve starts after AIP prep");
}

#[test]
fn run_condition_gs_smoke() {
    let Some(rt) = runtime() else { return };
    let mut cfg = ExperimentConfig::default();
    cfg.name = "smoke-gs".into();
    cfg.simulator = SimulatorKind::Gs;
    cfg.ppo.total_steps = 2048;
    cfg.eval_every = 2048;
    cfg.eval_episodes = 1;
    let r = ials::coordinator::run_condition(&rt, &cfg, 1).unwrap();
    assert_eq!(r.prep_secs, 0.0, "GS has no AIP prep");
    assert!(r.aip_ce.is_nan());
    assert!(r.final_eval.is_finite());
}
