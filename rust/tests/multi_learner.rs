//! Bitwise guarantees of the multi-learner runtime (`coordinator::multi`):
//!
//! 1. A `num_learners = 1` run is **bitwise identical** to the existing
//!    single-learner experiment path (shared collection + `Policy` +
//!    `train_with_eval`) at the same seed — the multi driver is a strict
//!    generalization, not a fork.
//! 2. A `num_learners = 3` run is **bitwise reproducible** across the
//!    full `num_workers × nn_workers ∈ {1, 2, 4} × {1, 4}` grid: learner
//!    seeding, round-robin order and the shared-pool scheduling can only
//!    change wall-clock, never bits.
//! 3. Learners are genuinely independent: learner 0 of a K = 3 run
//!    matches the K = 1 run exactly, while learners 1 and 2 train
//!    different policies from their own seed streams.
//!
//! Wall-clock fields (`wall_clock_s`, `prep_secs`, `train_secs`) are the
//! one exception — they measure real time and are excluded from the
//! comparisons, as in every other determinism test of the repo.

use ials::config::{BackendKind, DomainKind, ExperimentConfig, SimulatorKind};
use ials::coordinator::experiment::{
    make_eval_env, make_train_env, policy_model_name, prepare_predictor,
};
use ials::coordinator::{run_multi_condition, train_with_eval};
use ials::metrics::CurvePoint;
use ials::nn::ParamStore;
use ials::rl::Policy;
use ials::runtime::Runtime;
use std::rc::Rc;

/// Small fig3-style traffic IALS config: 2 PPO iterations over 8 envs,
/// one shared AIP dataset, native backend.
fn test_cfg(num_workers: usize, nn_workers: usize, num_learners: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "multi".into();
    cfg.domain = DomainKind::Traffic;
    cfg.simulator = SimulatorKind::Ials;
    cfg.num_learners = num_learners;
    cfg.seeds = vec![7];
    cfg.eval_every = 4096;
    cfg.eval_episodes = 1;
    cfg.ppo.num_envs = 8;
    cfg.ppo.rollout_len = 16;
    cfg.ppo.epochs = 2;
    cfg.ppo.minibatch = 32;
    cfg.ppo.total_steps = 256;
    cfg.ppo.num_workers = num_workers;
    cfg.aip.dataset_size = 1200;
    cfg.aip.eval_size = 800;
    cfg.aip.train_epochs = 1;
    cfg.aip.batch = 64;
    cfg.runtime.backend = BackendKind::Native;
    cfg.runtime.nn_workers = nn_workers;
    cfg.validate().unwrap();
    cfg
}

fn snapshot(store: &ParamStore) -> Vec<Vec<f32>> {
    store.names().iter().map(|n| store.get(n).unwrap().to_vec()).collect()
}

/// The bit-comparable content of a learning curve (wall-clock excluded).
#[allow(clippy::type_complexity)]
fn curve_bits(curve: &[CurvePoint]) -> Vec<(usize, u64, u64, [u32; 7], usize)> {
    curve
        .iter()
        .map(|p| {
            (
                p.env_steps,
                p.eval_mean.to_bits(),
                p.eval_std.to_bits(),
                [
                    p.stats.total_loss.to_bits(),
                    p.stats.pg_loss.to_bits(),
                    p.stats.v_loss.to_bits(),
                    p.stats.entropy.to_bits(),
                    p.stats.approx_kl.to_bits(),
                    p.stats.grad_norm.to_bits(),
                    p.stats.rollout_reward.to_bits(),
                ],
                p.stats.episodes,
            )
        })
        .collect()
}

#[test]
fn one_learner_run_is_bitwise_identical_to_single_learner_path() {
    let seed = 7u64;
    let cfg = test_cfg(1, 1, 1);
    let rt = Rc::new(Runtime::from_config(&cfg).unwrap());

    // The existing single-learner experiment (run_condition's exact body,
    // with the policy kept alive for a final parameter snapshot).
    let prep = prepare_predictor(&rt, &cfg, seed, cfg.ppo.num_envs).unwrap();
    let single_ce = prep.aip_ce;
    let mut train_env = make_train_env(&cfg, prep.predictor);
    let mut eval_env = make_eval_env(&cfg);
    let mut policy = Policy::new(rt.clone(), policy_model_name(&cfg), cfg.ppo.num_envs).unwrap();
    policy.reinit(seed).unwrap();
    let single = train_with_eval(
        &cfg,
        train_env.as_mut(),
        eval_env.as_mut(),
        &mut policy,
        seed,
        prep.prep_secs,
    )
    .unwrap();

    let multi = run_multi_condition(&rt, &cfg, seed).unwrap();
    assert_eq!(multi.results.len(), 1);
    assert_eq!(multi.policy_stores.len(), 1);
    assert_eq!(
        curve_bits(&multi.results[0].curve),
        curve_bits(&single.curve),
        "k=1 multi-learner curve diverged from the single-learner path"
    );
    assert_eq!(
        multi.results[0].aip_ce.to_bits(),
        single_ce.to_bits(),
        "k=1 AIP cross-entropy diverged"
    );
    assert_eq!(
        snapshot(&multi.policy_stores[0]),
        snapshot(&policy.store),
        "k=1 trained policy parameters diverged"
    );
}

/// One K = 3 run at a worker grid point: per-learner curve bits + final
/// per-learner policy parameters.
#[allow(clippy::type_complexity)]
fn run_k3(
    num_workers: usize,
    nn_workers: usize,
) -> (Vec<Vec<(usize, u64, u64, [u32; 7], usize)>>, Vec<Vec<Vec<f32>>>) {
    let cfg = test_cfg(num_workers, nn_workers, 3);
    let rt = Rc::new(Runtime::from_config(&cfg).unwrap());
    let out = run_multi_condition(&rt, &cfg, 21).unwrap();
    assert_eq!(out.results.len(), 3);
    let curves = out.results.iter().map(|r| curve_bits(&r.curve)).collect();
    let params = out.policy_stores.iter().map(snapshot).collect();
    (curves, params)
}

#[test]
fn three_learner_run_is_bitwise_reproducible_across_worker_grids() {
    let (ref_curves, ref_params) = run_k3(1, 1);
    // The learners really are three different policies (seed streams and
    // inits are per learner) trained to three different parameter sets.
    assert_ne!(ref_params[0], ref_params[1], "learners 0/1 trained identical policies");
    assert_ne!(ref_params[1], ref_params[2], "learners 1/2 trained identical policies");
    assert_ne!(ref_curves[0], ref_curves[1], "learners 0/1 produced identical curves");
    for (w, nn) in [(2usize, 1usize), (4, 1), (1, 4), (2, 4), (4, 4)] {
        let (curves, params) = run_k3(w, nn);
        assert_eq!(curves, ref_curves, "k=3 curves diverged at num_workers={w} nn_workers={nn}");
        assert_eq!(
            params, ref_params,
            "k=3 trained policies diverged at num_workers={w} nn_workers={nn}"
        );
    }
}

#[test]
fn learner_zero_of_a_multi_run_matches_the_single_learner_run() {
    let seed = 13u64;
    let cfg1 = test_cfg(1, 1, 1);
    let cfg3 = test_cfg(1, 1, 3);
    let rt = Rc::new(Runtime::from_config(&cfg3).unwrap());
    let one = run_multi_condition(&rt, &cfg1, seed).unwrap();
    let three = run_multi_condition(&rt, &cfg3, seed).unwrap();
    // Learner 0 is seeded by the base seed itself and consumes the same
    // shared dataset bits, so adding learners never perturbs it.
    assert_eq!(
        curve_bits(&three.results[0].curve),
        curve_bits(&one.results[0].curve),
        "learner 0 diverged when learners 1..3 joined the run"
    );
    assert_eq!(
        snapshot(&three.policy_stores[0]),
        snapshot(&one.policy_stores[0]),
        "learner 0 parameters diverged when learners 1..3 joined the run"
    );
    assert_ne!(three.results[0].seed, three.results[1].seed, "learner seeds must differ");
}
