//! Acceptance tests for the self-healing training runtime
//! (`runtime::guard` + the guarded driver in `coordinator::multi`):
//!
//! 1. **Determinism contract**: a guard-on clean run is bitwise identical
//!    to a guard-off run — curves, AIP cross-entropy and final policy
//!    parameters — because every health check is a pure read of metrics
//!    the trainer computes anyway.
//! 2. An injected numerical fault (NaN-poisoned parameters via
//!    `IALS_NAN_AT`, or a grad-norm spike via `IALS_GRAD_SPIKE_AT`)
//!    triggers an automatic rollback to the newest valid checkpoint, and
//!    the recovered run lands bitwise on the clean trajectory — and is
//!    reproducible run to run.
//! 3. A fault that re-fires on every replay (`:every`) exhausts
//!    `[health] max_rollbacks` and quarantines **only** the faulty
//!    learner: the other learners' curves and parameters are bitwise
//!    unchanged, and the binary exits nonzero with the health summary.
//!
//! Fault specs are read from process-global environment variables at
//! build time, so every in-process run here is serialized behind one
//! lock and scrubs both variables before setting its own.
//!
//! Wall-clock fields (`wall_clock_s`, `prep_secs`, `train_secs`) measure
//! real time and are excluded, as in every determinism test of the repo.

use ials::config::{BackendKind, DomainKind, ExperimentConfig, SimulatorKind};
use ials::coordinator::{run_multi_condition, run_multi_condition_resumable, MultiLearnerOutcome};
use ials::metrics::CurvePoint;
use ials::nn::ParamStore;
use ials::runtime::guard::LearnerHealth;
use ials::runtime::Runtime;
use ials::testkit::fault::{NAN_ENV, SPIKE_ENV};
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::Mutex;

/// Per-learner env steps in one PPO iteration of [`test_cfg`] runs.
const PER_ITER: usize = 8 * 16;

/// Serializes every in-process run: fault specs live in process-global
/// env vars, and Rust tests share one process.
static ENV_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` with exactly `vars` set (both fault variables scrubbed first),
/// holding the env lock for the duration.
fn with_fault_env<T>(vars: &[(&str, &str)], f: impl FnOnce() -> T) -> T {
    let _guard = ENV_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    std::env::remove_var(NAN_ENV);
    std::env::remove_var(SPIKE_ENV);
    for (k, v) in vars {
        std::env::set_var(k, v);
    }
    let r = f();
    for (k, _) in vars {
        std::env::remove_var(k);
    }
    r
}

/// Small fig3-style traffic IALS config (the `checkpoint_resume.rs`
/// shape): 8 envs × 16 rollout, 4 PPO iterations, a curve point every
/// iteration, native backend, one rollback in the budget.
fn test_cfg(num_learners: usize, ckpt_dir: &Path, checkpoint_every: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "health".into();
    cfg.domain = DomainKind::Traffic;
    cfg.simulator = SimulatorKind::Ials;
    cfg.num_learners = num_learners;
    cfg.seeds = vec![7];
    cfg.eval_every = PER_ITER;
    cfg.eval_episodes = 1;
    cfg.ppo.num_envs = 8;
    cfg.ppo.rollout_len = 16;
    cfg.ppo.epochs = 2;
    cfg.ppo.minibatch = 32;
    cfg.ppo.total_steps = 4 * PER_ITER;
    cfg.aip.dataset_size = 1200;
    cfg.aip.eval_size = 800;
    cfg.aip.train_epochs = 1;
    cfg.aip.batch = 64;
    cfg.runtime.backend = BackendKind::Native;
    cfg.checkpoint_every = checkpoint_every;
    cfg.checkpoint_dir = ckpt_dir.to_str().unwrap().to_string();
    cfg.health.max_rollbacks = 1;
    cfg.validate().unwrap();
    cfg
}

/// Fresh per-test root under the system temp dir.
fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ials_health_{tag}"));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn snapshot(store: &ParamStore) -> Vec<Vec<f32>> {
    store.names().iter().map(|n| store.get(n).unwrap().to_vec()).collect()
}

/// The bit-comparable content of a learning curve (wall-clock excluded).
#[allow(clippy::type_complexity)]
fn curve_bits(curve: &[CurvePoint]) -> Vec<(usize, u64, u64, [u32; 7], usize)> {
    curve
        .iter()
        .map(|p| {
            (
                p.env_steps,
                p.eval_mean.to_bits(),
                p.eval_std.to_bits(),
                [
                    p.stats.total_loss.to_bits(),
                    p.stats.pg_loss.to_bits(),
                    p.stats.v_loss.to_bits(),
                    p.stats.entropy.to_bits(),
                    p.stats.approx_kl.to_bits(),
                    p.stats.grad_norm.to_bits(),
                    p.stats.rollout_reward.to_bits(),
                ],
                p.stats.episodes,
            )
        })
        .collect()
}

/// Everything bit-comparable about an outcome: per-learner curve bits,
/// AIP cross-entropy bits and final policy parameters, in learner order.
#[allow(clippy::type_complexity)]
fn outcome_bits(
    out: &MultiLearnerOutcome,
) -> (Vec<Vec<(usize, u64, u64, [u32; 7], usize)>>, Vec<u64>, Vec<Vec<Vec<f32>>>) {
    (
        out.results.iter().map(|r| curve_bits(&r.curve)).collect(),
        out.results.iter().map(|r| r.aip_ce.to_bits()).collect(),
        out.policy_stores.iter().map(snapshot).collect(),
    )
}

/// (1) The determinism contract: enabling the guard on a clean run
/// changes nothing — not one bit of any curve, cross-entropy or final
/// parameter — because the checks only read metrics the trainer already
/// computes.
#[test]
fn guard_on_clean_run_is_bitwise_identical_to_guard_off() {
    let seed = 7u64;
    let dir = tmp_dir("clean");
    let cfg_on = test_cfg(2, &dir, 0);
    assert!(cfg_on.health.enabled, "the guard must default to on");
    let mut cfg_off = cfg_on.clone();
    cfg_off.health.enabled = false;
    let rt = Rc::new(Runtime::from_config(&cfg_on).unwrap());
    let on = with_fault_env(&[], || run_multi_condition(&rt, &cfg_on, seed).unwrap());
    let off = with_fault_env(&[], || run_multi_condition(&rt, &cfg_off, seed).unwrap());
    assert_eq!(
        outcome_bits(&on),
        outcome_bits(&off),
        "a guard-on clean run diverged from guard-off"
    );
    assert!(
        on.health.iter().all(|h| *h == LearnerHealth::default()),
        "a clean run must report no rollbacks and no quarantine: {:?}",
        on.health
    );
}

/// (2a) NaN-poisoned parameters: the param-norm check catches the
/// divergence, the learner rolls back to the newest checkpoint, replays
/// clean, and the whole run lands bitwise on the clean trajectory —
/// reproducibly, run to run.
#[test]
fn nan_fault_rolls_back_and_recovers_bitwise() {
    let seed = 7u64;
    let ref_dir = tmp_dir("nan_ref");
    let ref_cfg = test_cfg(2, &ref_dir, PER_ITER);
    let rt = Rc::new(Runtime::from_config(&ref_cfg).unwrap());
    let clean = with_fault_env(&[], || {
        outcome_bits(&run_multi_condition_resumable(&rt, &ref_cfg, seed, false, None).unwrap())
    });

    let mut recovered = Vec::new();
    for round_trip in 0..2 {
        let dir = tmp_dir(&format!("nan_{round_trip}"));
        let cfg = test_cfg(2, &dir, PER_ITER);
        let out = with_fault_env(&[(NAN_ENV, "0:2")], || {
            run_multi_condition_resumable(&rt, &cfg, seed, false, None).unwrap()
        });
        assert_eq!(
            out.health[0],
            LearnerHealth { quarantined: false, rollbacks: 1 },
            "learner 0 must recover via exactly one rollback"
        );
        assert_eq!(out.health[1], LearnerHealth::default(), "learner 1 was never faulted");
        assert!(!out.any_quarantined());
        recovered.push(outcome_bits(&out));
        std::fs::remove_dir_all(&dir).ok();
    }
    assert_eq!(recovered[0], clean, "the recovered run diverged from the clean trajectory");
    assert_eq!(recovered[0], recovered[1], "recovery is not reproducible run to run");
    std::fs::remove_dir_all(&ref_dir).ok();
}

/// (2b) A gradient-norm spike (metrics only — parameters untouched)
/// trips the rolling-window detector the same way and recovers bitwise.
#[test]
fn grad_spike_fault_rolls_back_and_recovers_bitwise() {
    let seed = 7u64;
    // A 1-deep window with a one-strike escalation makes the ×1000 spike
    // diverge immediately; spike_factor 50 keeps natural iteration-over-
    // iteration grad-norm swings far below the trigger.
    let tighten = |cfg: &mut ExperimentConfig| {
        cfg.health.window = 1;
        cfg.health.spike_factor = 50.0;
        cfg.health.max_anomalies = 1;
        cfg.validate().unwrap();
    };
    let ref_dir = tmp_dir("spike_ref");
    let mut ref_cfg = test_cfg(1, &ref_dir, PER_ITER);
    tighten(&mut ref_cfg);
    let rt = Rc::new(Runtime::from_config(&ref_cfg).unwrap());
    let clean_out = with_fault_env(&[], || {
        run_multi_condition_resumable(&rt, &ref_cfg, seed, false, None).unwrap()
    });
    assert_eq!(
        clean_out.health[0],
        LearnerHealth::default(),
        "the tightened detector must not fire on a clean run"
    );
    let clean = outcome_bits(&clean_out);

    let dir = tmp_dir("spike");
    let mut cfg = test_cfg(1, &dir, PER_ITER);
    tighten(&mut cfg);
    let out = with_fault_env(&[(SPIKE_ENV, "0:2")], || {
        run_multi_condition_resumable(&rt, &cfg, seed, false, None).unwrap()
    });
    assert_eq!(
        out.health[0],
        LearnerHealth { quarantined: false, rollbacks: 1 },
        "the spike must cost exactly one rollback"
    );
    assert_eq!(outcome_bits(&out), clean, "spike recovery diverged from the clean trajectory");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&ref_dir).ok();
}

/// (3) A fault that re-fires on every post-rollback replay exhausts the
/// budget: only the faulty learner is quarantined, its curve is a clean
/// prefix (it stops where the budget ran out), and every other learner
/// is bitwise untouched.
#[test]
fn exhausted_rollbacks_quarantine_only_the_faulty_learner() {
    let seed = 7u64;
    let ref_dir = tmp_dir("quar_ref");
    let ref_cfg = test_cfg(2, &ref_dir, PER_ITER);
    let rt = Rc::new(Runtime::from_config(&ref_cfg).unwrap());
    let clean = with_fault_env(&[], || {
        outcome_bits(&run_multi_condition_resumable(&rt, &ref_cfg, seed, false, None).unwrap())
    });

    let dir = tmp_dir("quar");
    let cfg = test_cfg(2, &dir, PER_ITER);
    let out = with_fault_env(&[(NAN_ENV, "1:2:every")], || {
        run_multi_condition_resumable(&rt, &cfg, seed, false, None).unwrap()
    });
    assert!(out.any_quarantined());
    assert_eq!(
        out.health[1],
        LearnerHealth { quarantined: true, rollbacks: 1 },
        "learner 1 must spend its whole budget, then be quarantined"
    );
    assert_eq!(out.health[0], LearnerHealth::default(), "learner 0 was never faulted");

    let (curves, ces, params) = outcome_bits(&out);
    let (clean_curves, clean_ces, clean_params) = clean;
    assert_eq!(curves[0], clean_curves[0], "learner 0's curve must be bitwise unchanged");
    assert_eq!(params[0], clean_params[0], "learner 0's parameters must be bitwise unchanged");
    assert_eq!(ces, clean_ces, "AIP preparation happens before any fault");
    // The quarantined learner trained through iteration 2 (t=0 plus two
    // per-iteration points) and its replayed points are clean bits — the
    // poison lands on the parameters *after* each point is recorded.
    assert_eq!(curves[1].len(), 3, "learner 1 must stop at its quarantine point");
    assert_eq!(
        curves[1],
        clean_curves[1][..3].to_vec(),
        "learner 1's curve must be a clean prefix"
    );
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&ref_dir).ok();
}

/// Divergence with no valid checkpoint to roll back to (checkpointing
/// disabled) quarantines immediately — without spending rollback budget.
#[test]
fn fault_without_checkpoint_quarantines_without_spending_budget() {
    let seed = 7u64;
    let dir = tmp_dir("nockpt");
    let cfg = test_cfg(1, &dir, 0);
    let rt = Rc::new(Runtime::from_config(&cfg).unwrap());
    let out = with_fault_env(&[(NAN_ENV, "0:2")], || {
        run_multi_condition(&rt, &cfg, seed).unwrap()
    });
    assert_eq!(
        out.health[0],
        LearnerHealth { quarantined: true, rollbacks: 0 },
        "no checkpoint means immediate quarantine, budget untouched"
    );
}

/// End to end through the real binary: a quarantined learner makes
/// `repro train` print the per-learner health summary and exit nonzero,
/// while the healthy learners' curves still land on disk.
#[test]
fn quarantine_drives_a_nonzero_exit_with_health_report() {
    let seed = 7u64;
    let dir = tmp_dir("exit");
    std::fs::create_dir_all(&dir).unwrap();
    let mut cfg = test_cfg(2, &dir.join("ckpt"), PER_ITER);
    cfg.results_dir = dir.join("results").to_str().unwrap().to_string();
    let config_path = dir.join("health.toml");
    std::fs::write(&config_path, cfg.to_toml_string()).unwrap();

    let out = std::process::Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["train", "--config", config_path.to_str().unwrap(), "--seed", "7"])
        .env(NAN_ENV, "1:2:every")
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        !out.status.success(),
        "a quarantined learner must fail the run\nstdout:\n{stdout}\nstderr:\n{stderr}"
    );
    assert!(
        stdout.contains("QUARANTINED"),
        "the health summary must name the quarantined learner\nstdout:\n{stdout}"
    );
    assert!(
        stderr.contains("quarantined"),
        "the exit error must explain the degradation\nstderr:\n{stderr}"
    );
    assert!(
        stderr.contains("rolled back to checkpoint"),
        "the rollback attempt must be logged\nstderr:\n{stderr}"
    );
    // The healthy learner's curve still landed.
    let healthy_curve =
        format!("{}/ials-health_seed{seed}_learner0.csv", cfg.results_dir);
    assert!(
        std::path::Path::new(&healthy_curve).exists(),
        "healthy learners must still produce curves: missing {healthy_curve}"
    );
    std::fs::remove_dir_all(&dir).ok();
}
