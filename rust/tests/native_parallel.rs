//! Bitwise determinism of the data-parallel native NN engine: at a fixed
//! seed, `[runtime] nn_workers ∈ {2, 3, 4}` must produce **bitwise
//! identical** trained parameters, logits and episode metrics to
//! `nn_workers = 1`, for both domains — including worker counts that do
//! not divide the batch / minibatch. This is the NN-half counterpart of
//! `integration_parallel.rs` (which pins the sim half): batch rows
//! partition over a fixed slice grid and per-slice gradient partials
//! reduce in fixed slice order, so the worker count can only change
//! wall-clock, never bits.
//!
//! The same harness also pins the **fused step pipeline** end to end:
//! whole training runs (collect → AIP training → PPO on the IALS) with
//! the fused single-dispatch step must be bitwise identical to the PR 3
//! sandwich for any `num_workers` × `nn_workers` combination.

use ials::collect::{collect_dataset_sharded, FeatureKind};
use ials::config::{PpoConfig, TrafficConfig, WarehouseConfig};
use ials::core::VecEnv;
use ials::ials::IalsVecEnv;
use ials::influence::{train_fnn, train_gru, InfluencePredictor, NeuralAip};
use ials::nn::ParamStore;
use ials::rl::{Policy, PpoTrainer};
use ials::runtime::{Runtime, SynthGeometry};
use ials::sim::traffic::{TrafficGlobalEnv, TrafficLocalEnv};
use ials::sim::warehouse::{WarehouseGlobalEnv, WarehouseLocalEnv};
use ials::util::Pcg32;
use std::rc::Rc;

/// Everything a short training run produces that could possibly diverge.
struct RunOut {
    policy_params: Vec<Vec<f32>>,
    aip_params: Vec<Vec<f32>>,
    aip_losses: Vec<f32>,
    logits: Vec<f32>,
    values: Vec<f32>,
    /// `[total_loss, approx_kl, rollout_reward]` per PPO iteration.
    metrics: Vec<[f32; 3]>,
}

fn snapshot(store: &ParamStore) -> Vec<Vec<f32>> {
    store.names().iter().map(|n| store.get(n).unwrap().to_vec()).collect()
}

fn assert_bitwise_eq(a: &RunOut, b: &RunOut, what: &str) {
    assert_eq!(a.aip_losses, b.aip_losses, "{what}: AIP training losses diverged");
    assert_eq!(a.aip_params, b.aip_params, "{what}: trained AIP parameters diverged");
    assert_eq!(a.metrics, b.metrics, "{what}: PPO episode metrics diverged");
    assert_eq!(a.policy_params, b.policy_params, "{what}: trained policy parameters diverged");
    assert_eq!(a.logits, b.logits, "{what}: post-training logits diverged");
    assert_eq!(a.values, b.values, "{what}: post-training values diverged");
}

/// Short fig3-style traffic IALS training: Algorithm-1 collect → FNN AIP
/// training → 2 PPO iterations on the IALS (fused whole-phase updates).
/// `sim_workers` shards the env, `nn_workers` fans NN rows out, `fused`
/// selects the single-dispatch step pipeline vs the PR 3 sandwich.
fn run_traffic(nn_workers: usize, sim_workers: usize, fused: bool) -> RunOut {
    let geom = SynthGeometry {
        rollout_b: 8,
        rollout_t: 16,
        ppo_epochs: 2,
        ppo_minibatch: 32,
        aip_batch: 64,
        ..SynthGeometry::default()
    };
    let rt = Rc::new(Runtime::native_parallel(&geom, nn_workers));
    let seed = 7u64;
    let tcfg = TrafficConfig::default();

    let data = collect_dataset_sharded(
        || TrafficGlobalEnv::new(&tcfg),
        1500,
        seed,
        FeatureKind::Dset,
        1,
    );
    let mut aip = NeuralAip::new(rt.clone(), "aip_traffic", 8).unwrap();
    let spec = rt.manifest.model("aip_traffic").unwrap().clone();
    aip.store.reinit(&spec, seed ^ 0xA1B2);
    let aip_losses =
        train_fnn(&rt, &mut aip.store, "aip_traffic_update", &data, 1, 64, 1e-3, seed).unwrap();
    let aip_params = snapshot(&aip.store);

    let envs: Vec<TrafficLocalEnv> = (0..8).map(|_| TrafficLocalEnv::new(&tcfg)).collect();
    let mut env = IalsVecEnv::with_workers(envs, Box::new(aip), sim_workers);
    env.set_fused(fused);
    let cfg = PpoConfig {
        num_envs: 8,
        rollout_len: 16,
        epochs: 2,
        minibatch: 32,
        lr: 1e-3,
        ..PpoConfig::default()
    };
    let mut policy = Policy::new(rt.clone(), "policy_traffic", 8).unwrap();
    policy.reinit(seed).unwrap();
    let mut trainer = PpoTrainer::new(&cfg, env.obs_dim(), seed);
    env.reset_all(seed);
    let mut metrics = Vec::new();
    for _ in 0..2 {
        let s = trainer.train_iteration(&mut env, &mut policy).unwrap();
        metrics.push([s.total_loss, s.approx_kl, s.rollout_reward]);
    }

    let mut rng = Pcg32::seeded(99);
    let obs: Vec<f32> = (0..8 * policy.obs_dim).map(|_| rng.f32() - 0.5).collect();
    let mut logits = vec![0.0f32; 8 * policy.act_dim];
    let mut values = vec![0.0f32; 8];
    policy.forward_into(&obs, &mut logits, &mut values).unwrap();
    RunOut {
        policy_params: snapshot(&policy.store),
        aip_params,
        aip_losses,
        logits,
        values,
        metrics,
    }
}

/// Short fig5-style warehouse GRU-IALS training: collect → GRU BPTT AIP
/// training → 2 PPO iterations on the IALS with the recurrent predictor.
fn run_warehouse(nn_workers: usize, sim_workers: usize, fused: bool) -> RunOut {
    let geom = SynthGeometry {
        rollout_b: 8,
        rollout_t: 16,
        ppo_epochs: 2,
        ppo_minibatch: 32,
        gru_seq_b: 8,
        gru_seq_t: 8,
        ..SynthGeometry::default()
    };
    let rt = Rc::new(Runtime::native_parallel(&geom, nn_workers));
    let seed = 11u64;
    let wcfg = WarehouseConfig::default();

    let data = collect_dataset_sharded(
        || WarehouseGlobalEnv::new(&wcfg),
        1200,
        seed,
        FeatureKind::Dset,
        1,
    );
    let mut aip = NeuralAip::new(rt.clone(), "aip_warehouse", 8).unwrap();
    let spec = rt.manifest.model("aip_warehouse").unwrap().clone();
    aip.store.reinit(&spec, seed ^ 0xA1B2);
    let aip_losses =
        train_gru(&rt, &mut aip.store, "aip_warehouse_update", &data, 1, 8, 8, 1e-3, seed)
            .unwrap();
    let aip_params = snapshot(&aip.store);

    let envs: Vec<WarehouseLocalEnv> = (0..8).map(|_| WarehouseLocalEnv::new(&wcfg)).collect();
    let mut env = IalsVecEnv::with_workers(envs, Box::new(aip), sim_workers);
    env.set_fused(fused);
    let cfg = PpoConfig {
        num_envs: 8,
        rollout_len: 16,
        epochs: 2,
        minibatch: 32,
        lr: 1e-3,
        ..PpoConfig::default()
    };
    let mut policy = Policy::new(rt.clone(), "policy_warehouse_nm", 8).unwrap();
    policy.reinit(seed).unwrap();
    let mut trainer = PpoTrainer::new(&cfg, env.obs_dim(), seed);
    env.reset_all(seed);
    let mut metrics = Vec::new();
    for _ in 0..2 {
        let s = trainer.train_iteration(&mut env, &mut policy).unwrap();
        metrics.push([s.total_loss, s.approx_kl, s.rollout_reward]);
    }

    let mut rng = Pcg32::seeded(101);
    let obs: Vec<f32> = (0..8 * policy.obs_dim).map(|_| rng.f32() - 0.5).collect();
    let mut logits = vec![0.0f32; 8 * policy.act_dim];
    let mut values = vec![0.0f32; 8];
    policy.forward_into(&obs, &mut logits, &mut values).unwrap();
    RunOut {
        policy_params: snapshot(&policy.store),
        aip_params,
        aip_losses,
        logits,
        values,
        metrics,
    }
}

#[test]
fn traffic_fig3_training_is_nn_worker_count_invariant() {
    let reference = run_traffic(1, 1, true);
    assert!(
        reference.metrics.iter().all(|m| m.iter().all(|x| x.is_finite())),
        "reference metrics must be finite"
    );
    // 3 does not divide the minibatch (32), the rollout (128) or the slice
    // grid — the fixed-grid + ordered-reduction scheme must not care.
    for k in [2usize, 3, 4] {
        let other = run_traffic(k, 1, true);
        assert_bitwise_eq(&reference, &other, &format!("traffic nn_workers={k}"));
    }
}

#[test]
fn warehouse_fig5_gru_training_is_nn_worker_count_invariant() {
    let reference = run_warehouse(1, 1, true);
    for k in [2usize, 3, 4] {
        let other = run_warehouse(k, 1, true);
        assert_bitwise_eq(&reference, &other, &format!("warehouse nn_workers={k}"));
    }
}

#[test]
fn traffic_fig3_fused_training_equals_pr3_sandwich() {
    // The acceptance bar of the fused-pipeline PR: whole training runs
    // through the fused single-dispatch step must be bitwise identical to
    // the PR 3 sandwich for any num_workers × nn_workers — including
    // worker counts (3, 5) that do not divide the batch of 8.
    let sandwich = run_traffic(1, 1, false);
    // The sandwich itself must also stay worker-invariant — it remains the
    // shipping path for PJRT-backed predictors (coordinator-batched AIP
    // call whose rows fan out over nn_workers).
    let sandwich_par = run_traffic(3, 2, false);
    assert_bitwise_eq(&sandwich, &sandwich_par, "traffic sandwich nn_workers=3 num_workers=2");
    for (nn, sim) in [(1usize, 1usize), (2, 3), (3, 4), (4, 2), (2, 5)] {
        let fused = run_traffic(nn, sim, true);
        assert_bitwise_eq(
            &sandwich,
            &fused,
            &format!("traffic fused nn_workers={nn} num_workers={sim}"),
        );
    }
}

#[test]
fn warehouse_fig5_fused_gru_training_equals_pr3_sandwich() {
    // Same bar for the recurrent predictor: the fused dispatch advances
    // (and episode-resets) each shard's band of the GRU state, which must
    // reproduce the sandwich's coordinator-side h handling exactly.
    let sandwich = run_warehouse(1, 1, false);
    for (nn, sim) in [(2usize, 3usize), (3, 2), (4, 4)] {
        let fused = run_warehouse(nn, sim, true);
        assert_bitwise_eq(
            &sandwich,
            &fused,
            &format!("warehouse fused nn_workers={nn} num_workers={sim}"),
        );
    }
}

#[test]
fn parallel_forwards_match_serial_bitwise_above_threshold() {
    // Batch 256 is far above the parallel-engagement threshold, so the
    // pooled runtime actually fans out — and must still be bitwise equal.
    let geom = SynthGeometry { rollout_b: 256, ..SynthGeometry::default() };
    let serial = Rc::new(Runtime::native(&geom));
    let parallel = Rc::new(Runtime::native_parallel(&geom, 4));

    let mut rng = Pcg32::seeded(5);
    let obs: Vec<f32> = (0..256 * 42).map(|_| rng.f32() - 0.5).collect();
    let mut policy_s = Policy::new(serial.clone(), "policy_traffic", 256).unwrap();
    let mut policy_p = Policy::new(parallel.clone(), "policy_traffic", 256).unwrap();
    let (mut la, mut lb) = (vec![0.0f32; 256 * 2], vec![0.0f32; 256 * 2]);
    let (mut va, mut vb) = (vec![0.0f32; 256], vec![0.0f32; 256]);
    policy_s.forward_into(&obs, &mut la, &mut va).unwrap();
    policy_p.forward_into(&obs, &mut lb, &mut vb).unwrap();
    assert_eq!(la, lb, "policy logits diverged");
    assert_eq!(va, vb, "policy values diverged");

    // Recurrent AIP step (GRU cell + head) over a few steps of state.
    let mut gru_s = NeuralAip::new(serial, "aip_warehouse", 256).unwrap();
    let mut gru_p = NeuralAip::new(parallel, "aip_warehouse", 256).unwrap();
    let dsets: Vec<f32> = (0..256 * 24).map(|_| rng.f32()).collect();
    let (mut pa, mut pb) = (vec![0.0f32; 256 * 12], vec![0.0f32; 256 * 12]);
    for _ in 0..3 {
        gru_s.predict(&dsets, &mut pa).unwrap();
        gru_p.predict(&dsets, &mut pb).unwrap();
        assert_eq!(pa, pb, "GRU AIP probs diverged");
    }
}
