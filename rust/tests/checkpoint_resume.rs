//! Crash-safety acceptance tests for the checkpoint/resume runtime
//! (`runtime::checkpoint` + `coordinator::run_multi_condition_resumable`):
//!
//! 1. A run killed after iteration M (via the fault-injection hook) and
//!    resumed from its newest checkpoint is **bitwise identical** to the
//!    uninterrupted run — curves, AIP cross-entropy and final policy
//!    parameters — for K ∈ {1, 3} learners across the full
//!    `num_workers × nn_workers ∈ {1, 2, 4} × {1, 4}` grid.
//! 2. When the newest checkpoint on disk is corrupted (bit flip) or torn
//!    (truncation), resume falls back to the previous *valid* one and
//!    still reproduces the uninterrupted run bit for bit.
//! 3. `--resume` with no valid checkpoint is a clean, actionable error;
//!    resuming under a different run geometry is a structured mismatch
//!    error, never a silently-diverging run.
//!
//! Wall-clock fields (`wall_clock_s`, `prep_secs`, `train_secs`) measure
//! real time and are excluded, as in every determinism test of the repo.

use ials::config::{BackendKind, DomainKind, ExperimentConfig, SimulatorKind};
use ials::coordinator::{checkpoint_run_dir, run_multi_condition_resumable, MultiLearnerOutcome};
use ials::metrics::CurvePoint;
use ials::nn::ParamStore;
use ials::runtime::Runtime;
use ials::testkit::fault::{flip_bit, truncate_file};
use std::path::PathBuf;
use std::rc::Rc;

/// Per-learner env steps in one PPO iteration of [`test_cfg`] runs.
const PER_ITER: usize = 8 * 16;

/// Small fig3-style traffic IALS config — the `multi_learner.rs` shape
/// (8 envs × 16 rollout, native backend) at 3 PPO iterations, saving a
/// checkpoint every iteration into `ckpt_dir`.
fn test_cfg(
    num_workers: usize,
    nn_workers: usize,
    num_learners: usize,
    ckpt_dir: &std::path::Path,
    checkpoint_every: usize,
) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "ckptres".into();
    cfg.domain = DomainKind::Traffic;
    cfg.simulator = SimulatorKind::Ials;
    cfg.num_learners = num_learners;
    cfg.seeds = vec![7];
    cfg.eval_every = 4096;
    cfg.eval_episodes = 1;
    cfg.ppo.num_envs = 8;
    cfg.ppo.rollout_len = 16;
    cfg.ppo.epochs = 2;
    cfg.ppo.minibatch = 32;
    cfg.ppo.total_steps = 3 * PER_ITER;
    cfg.ppo.num_workers = num_workers;
    cfg.aip.dataset_size = 1200;
    cfg.aip.eval_size = 800;
    cfg.aip.train_epochs = 1;
    cfg.aip.batch = 64;
    cfg.runtime.backend = BackendKind::Native;
    cfg.runtime.nn_workers = nn_workers;
    cfg.checkpoint_every = checkpoint_every;
    cfg.checkpoint_dir = ckpt_dir.to_str().unwrap().to_string();
    cfg.validate().unwrap();
    cfg
}

/// Fresh per-test checkpoint root under the system temp dir.
fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ials_ckpt_resume_{tag}"));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn snapshot(store: &ParamStore) -> Vec<Vec<f32>> {
    store.names().iter().map(|n| store.get(n).unwrap().to_vec()).collect()
}

/// The bit-comparable content of a learning curve (wall-clock excluded).
#[allow(clippy::type_complexity)]
fn curve_bits(curve: &[CurvePoint]) -> Vec<(usize, u64, u64, [u32; 7], usize)> {
    curve
        .iter()
        .map(|p| {
            (
                p.env_steps,
                p.eval_mean.to_bits(),
                p.eval_std.to_bits(),
                [
                    p.stats.total_loss.to_bits(),
                    p.stats.pg_loss.to_bits(),
                    p.stats.v_loss.to_bits(),
                    p.stats.entropy.to_bits(),
                    p.stats.approx_kl.to_bits(),
                    p.stats.grad_norm.to_bits(),
                    p.stats.rollout_reward.to_bits(),
                ],
                p.stats.episodes,
            )
        })
        .collect()
}

/// Everything bit-comparable about an outcome: per-learner curve bits,
/// AIP cross-entropy bits and final policy parameters, in learner order.
#[allow(clippy::type_complexity)]
fn outcome_bits(
    out: &MultiLearnerOutcome,
) -> (Vec<Vec<(usize, u64, u64, [u32; 7], usize)>>, Vec<u64>, Vec<Vec<Vec<f32>>>) {
    (
        out.results.iter().map(|r| curve_bits(&r.curve)).collect(),
        out.results.iter().map(|r| r.aip_ce.to_bits()).collect(),
        out.policy_stores.iter().map(snapshot).collect(),
    )
}

/// Train `cfg` to completion with an injected crash after iteration
/// `abort_at`, then resume from disk; returns the resumed outcome.
fn crash_and_resume(cfg: &ExperimentConfig, seed: u64, abort_at: usize) -> MultiLearnerOutcome {
    let rt = Rc::new(Runtime::from_config(cfg).unwrap());
    let err = run_multi_condition_resumable(&rt, cfg, seed, false, Some(abort_at))
        .err()
        .expect("injected abort must fail the run");
    let msg = format!("{err:#}");
    assert!(msg.contains("injected abort"), "unexpected failure mode: {msg}");
    let run_dir = checkpoint_run_dir(cfg, seed);
    assert!(
        std::fs::read_dir(&run_dir).map(|d| d.count() > 0).unwrap_or(false),
        "aborted run left no checkpoint in {}",
        run_dir.display()
    );
    run_multi_condition_resumable(&rt, cfg, seed, true, None).unwrap()
}

/// Newest `ckpt_*.bin` in the run directory of `(cfg, seed)`.
fn newest_checkpoint(cfg: &ExperimentConfig, seed: u64) -> PathBuf {
    let run_dir = checkpoint_run_dir(cfg, seed);
    let mut files: Vec<PathBuf> = std::fs::read_dir(&run_dir)
        .unwrap()
        .flatten()
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("ckpt_") && n.ends_with(".bin"))
        })
        .collect();
    files.sort();
    files.pop().unwrap_or_else(|| panic!("no checkpoint files in {}", run_dir.display()))
}

/// The acceptance grid: kill-at-iteration-M + resume is bitwise identical
/// to the uninterrupted run for K ∈ {1, 3} across `num_workers ×
/// nn_workers ∈ {1, 2, 4} × {1, 4}`. The kill point alternates between
/// iteration 1 and 2 (of 3) across the grid so both resume depths are
/// covered.
#[test]
fn kill_and_resume_is_bitwise_identical_across_learners_and_workers() {
    let seed = 7u64;
    for k in [1usize, 3] {
        // Uninterrupted reference (no checkpointing): worker counts never
        // change bits, so one reference serves the whole grid.
        let ref_dir = tmp_dir(&format!("ref_k{k}"));
        let ref_cfg = test_cfg(1, 1, k, &ref_dir, 0);
        let rt = Rc::new(Runtime::from_config(&ref_cfg).unwrap());
        let reference =
            outcome_bits(&run_multi_condition_resumable(&rt, &ref_cfg, seed, false, None).unwrap());
        for (i, (w, nn)) in
            [(1usize, 1usize), (2, 1), (4, 1), (1, 4), (2, 4), (4, 4)].iter().enumerate()
        {
            let abort_at = 1 + (i % 2);
            let dir = tmp_dir(&format!("grid_k{k}_w{w}_nn{nn}"));
            let cfg = test_cfg(*w, *nn, k, &dir, PER_ITER);
            let resumed = outcome_bits(&crash_and_resume(&cfg, seed, abort_at));
            assert_eq!(
                resumed, reference,
                "resumed run diverged from uninterrupted at k={k} num_workers={w} \
                 nn_workers={nn} abort_at={abort_at}"
            );
            std::fs::remove_dir_all(&dir).ok();
        }
        std::fs::remove_dir_all(&ref_dir).ok();
    }
}

/// A bit-flipped newest checkpoint is skipped: resume falls back to the
/// previous valid file and still reproduces the uninterrupted run.
#[test]
fn corrupt_newest_checkpoint_falls_back_and_still_matches() {
    let seed = 7u64;
    let ref_dir = tmp_dir("flip_ref");
    let ref_cfg = test_cfg(1, 1, 1, &ref_dir, 0);
    let rt = Rc::new(Runtime::from_config(&ref_cfg).unwrap());
    let reference =
        outcome_bits(&run_multi_condition_resumable(&rt, &ref_cfg, seed, false, None).unwrap());

    let dir = tmp_dir("flip");
    let cfg = test_cfg(1, 1, 1, &dir, PER_ITER);
    let err = run_multi_condition_resumable(&rt, &cfg, seed, false, Some(2))
        .err()
        .expect("run must fail");
    assert!(format!("{err:#}").contains("injected abort"));
    // Checkpoints exist for iterations 1 and 2; silently corrupt a payload
    // bit of the newest (iteration 2) file.
    flip_bit(newest_checkpoint(&cfg, seed), 40, 3).unwrap();
    let resumed =
        outcome_bits(&run_multi_condition_resumable(&rt, &cfg, seed, true, None).unwrap());
    assert_eq!(resumed, reference, "fallback resume after a bit flip diverged");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&ref_dir).ok();
}

/// A torn (truncated) newest checkpoint is skipped the same way.
#[test]
fn truncated_newest_checkpoint_falls_back_and_still_matches() {
    let seed = 7u64;
    let ref_dir = tmp_dir("trunc_ref");
    let ref_cfg = test_cfg(1, 1, 1, &ref_dir, 0);
    let rt = Rc::new(Runtime::from_config(&ref_cfg).unwrap());
    let reference =
        outcome_bits(&run_multi_condition_resumable(&rt, &ref_cfg, seed, false, None).unwrap());

    let dir = tmp_dir("trunc");
    let cfg = test_cfg(1, 1, 1, &dir, PER_ITER);
    let err = run_multi_condition_resumable(&rt, &cfg, seed, false, Some(2))
        .err()
        .expect("run must fail");
    assert!(format!("{err:#}").contains("injected abort"));
    // Tear the newest file mid-header: shorter than the 24-byte header.
    truncate_file(newest_checkpoint(&cfg, seed), 16).unwrap();
    let resumed =
        outcome_bits(&run_multi_condition_resumable(&rt, &cfg, seed, true, None).unwrap());
    assert_eq!(resumed, reference, "fallback resume after truncation diverged");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&ref_dir).ok();
}

/// `--resume` with no checkpoint on disk is a clean, actionable error.
#[test]
fn resume_without_checkpoints_is_a_clean_error() {
    let seed = 7u64;
    let dir = tmp_dir("nockpt");
    let cfg = test_cfg(1, 1, 1, &dir, PER_ITER);
    let rt = Rc::new(Runtime::from_config(&cfg).unwrap());
    let err = run_multi_condition_resumable(&rt, &cfg, seed, true, None)
        .err()
        .expect("run must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("no valid checkpoint"), "unhelpful resume error: {msg}");
    assert!(msg.contains("checkpoint_every"), "error should say how to fix it: {msg}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Resuming under a different run geometry (here: a different learner
/// count) is a structured mismatch error, not a diverging run.
#[test]
fn resume_with_mismatched_geometry_is_a_structured_error() {
    let seed = 7u64;
    let dir = tmp_dir("mismatch");
    let cfg = test_cfg(1, 1, 1, &dir, PER_ITER);
    let rt = Rc::new(Runtime::from_config(&cfg).unwrap());
    let err = run_multi_condition_resumable(&rt, &cfg, seed, false, Some(1))
        .err()
        .expect("run must fail");
    assert!(format!("{err:#}").contains("injected abort"));
    // Same condition name + seed (thus the same run directory), but a
    // 3-learner geometry.
    let cfg3 = test_cfg(1, 1, 3, &dir, PER_ITER);
    let rt3 = Rc::new(Runtime::from_config(&cfg3).unwrap());
    let err = run_multi_condition_resumable(&rt3, &cfg3, seed, true, None)
        .err()
        .expect("run must fail");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("1 learner(s)") && msg.contains("3"),
        "geometry mismatch must be a structured error: {msg}"
    );
    std::fs::remove_dir_all(&dir).ok();
}
