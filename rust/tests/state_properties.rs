//! Property-style tests for the headered durable-blob format
//! (`util::state::write_headered` / `read_headered`), the foundation
//! every crash-safe artifact of the crate sits on (checkpoints, the
//! distributed AIP dataset and shard results):
//!
//! 1. Round trip: for payload sizes from empty through a megabyte-minus-
//!    one, what is written is read back byte for byte.
//! 2. Corruption matrix: every injector of `testkit::fault` (truncation
//!    at several depths, a bit flip anywhere in the file, zeroing) makes
//!    `read_headered` return a *structured error* naming the failure —
//!    never a panic, never silently-wrong bytes.

use ials::testkit::fault::{flip_bit, truncate_file, zero_file};
use ials::util::state::{read_headered, write_headered, HEADER_LEN};
use ials::util::Pcg32;
use std::path::PathBuf;

const MAGIC: &[u8; 8] = b"IALSTEST";
const VERSION: u32 = 3;

/// Payload sizes covering the edge cases: empty, single byte, smaller
/// than the header, one page, and a large non-round size.
const SIZES: &[usize] = &[0, 1, 7, 4096, (1 << 20) - 1];

fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("ials_state_properties");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(tag)
}

/// Deterministic pseudo-random payload of length `n`.
fn payload(n: usize, seed: u64) -> Vec<u8> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| rng.next_u32() as u8).collect()
}

#[test]
fn roundtrip_across_payload_sizes() {
    for (i, &n) in SIZES.iter().enumerate() {
        let path = tmp(&format!("roundtrip_{n}.bin"));
        let data = payload(n, i as u64);
        write_headered(&path, MAGIC, VERSION, &data).unwrap();
        assert_eq!(
            std::fs::metadata(&path).unwrap().len() as usize,
            HEADER_LEN + n,
            "file size must be header + payload for n={n}"
        );
        let back = read_headered(&path, MAGIC, VERSION).unwrap();
        assert_eq!(back, data, "payload of {n} bytes did not round-trip");
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn wrong_magic_and_version_are_structured_errors() {
    let path = tmp("magic_version.bin");
    write_headered(&path, MAGIC, VERSION, &payload(64, 9)).unwrap();
    let err = format!("{:#}", read_headered(&path, b"OTHERFMT", VERSION).unwrap_err());
    assert!(err.contains("bad magic"), "foreign magic must be named: {err}");
    let err = format!("{:#}", read_headered(&path, MAGIC, VERSION + 1).unwrap_err());
    assert!(
        err.contains("version") && err.contains(&VERSION.to_string()),
        "version skew must name both versions: {err}"
    );
    std::fs::remove_file(&path).ok();
}

/// Truncation at every interesting depth — mid-magic, mid-header, exactly
/// the header (payload gone), and mid-payload — errors with a reason.
#[test]
fn truncation_matrix_errors_never_panics() {
    for &n in SIZES {
        // Truncation points: inside the magic, inside the length/CRC
        // fields, exactly at the header boundary, and mid-payload.
        for cut in [0usize, 3, 12, HEADER_LEN, HEADER_LEN + n / 2] {
            if cut >= HEADER_LEN + n {
                continue;
            }
            let path = tmp(&format!("trunc_{n}_{cut}.bin"));
            write_headered(&path, MAGIC, VERSION, &payload(n, 17)).unwrap();
            truncate_file(&path, cut).unwrap();
            let err = read_headered(&path, MAGIC, VERSION)
                .expect_err("a truncated file must be rejected");
            let msg = format!("{err:#}");
            assert!(
                msg.contains("truncated") || msg.contains("empty"),
                "truncation to {cut} of {} bytes must be named: {msg}",
                HEADER_LEN + n
            );
            std::fs::remove_file(&path).ok();
        }
    }
}

/// A single flipped bit anywhere — magic, version, length, CRC or payload
/// — is always caught by one of the header checks.
#[test]
fn bit_flip_matrix_errors_never_panics() {
    for &n in SIZES {
        let total = HEADER_LEN + n;
        // Offsets sweep every header field plus payload positions (the
        // flip_bit injector wraps offsets, so all are in range).
        for (i, offset) in
            [0usize, 9, 13, 21, HEADER_LEN, total - 1, total / 2].into_iter().enumerate()
        {
            if offset >= total && n == 0 {
                continue;
            }
            let path = tmp(&format!("flip_{n}_{i}.bin"));
            write_headered(&path, MAGIC, VERSION, &payload(n, 23)).unwrap();
            flip_bit(&path, offset, (i % 8) as u8).unwrap();
            let err = read_headered(&path, MAGIC, VERSION)
                .expect_err("a bit-flipped file must be rejected");
            // Any structured rejection is acceptable (magic, version,
            // length or CRC, depending on which byte the flip landed in);
            // the property is: error, never panic, never wrong bytes.
            let msg = format!("{err:#}");
            assert!(!msg.is_empty());
            std::fs::remove_file(&path).ok();
        }
    }
}

/// A corrupt length field claiming a huge payload must be rejected by
/// comparison against the file's actual size *before* any payload-sized
/// allocation — a bit-flipped `payload_len` of terabytes is a structured
/// error naming both numbers, never an attempted huge allocation (the
/// `read_headered` defensive bound).
#[test]
fn corrupt_length_field_is_bounded_before_allocation() {
    for &n in &[0usize, 7, 4096] {
        let path = tmp(&format!("hugelen_{n}.bin"));
        write_headered(&path, MAGIC, VERSION, &payload(n, 41)).unwrap();
        // Overwrite the length field (bytes 12..20) with an absurd claim;
        // magic, version and CRC stay intact so the length check itself
        // must be the one that fires.
        let mut bytes = std::fs::read(&path).unwrap();
        let huge: u64 = 1 << 45; // 32 TiB
        bytes[12..20].copy_from_slice(&huge.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = read_headered(&path, MAGIC, VERSION)
            .expect_err("a corrupt length field must be rejected");
        let msg = format!("{err:#}");
        assert!(
            msg.contains(&huge.to_string()) && msg.contains(&n.to_string()),
            "the error must name the claimed and actual payload sizes: {msg}"
        );
        assert!(msg.contains("not allocating"), "the remedy must be named: {msg}");
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn zeroed_file_is_a_structured_error() {
    for &n in &[0usize, 4096] {
        let path = tmp(&format!("zero_{n}.bin"));
        write_headered(&path, MAGIC, VERSION, &payload(n, 31)).unwrap();
        zero_file(&path).unwrap();
        let msg = format!(
            "{:#}",
            read_headered(&path, MAGIC, VERSION).expect_err("an empty file must be rejected")
        );
        assert!(msg.contains("empty"), "zeroing must be named: {msg}");
        std::fs::remove_file(&path).ok();
    }
}
